//! Multi-device sharding properties (DESIGN.md S18, no artifacts
//! needed): randomized `ArchSpec` sweeps over `multi::partition`
//! (contiguous, covering, within the device count, finite FPS), shard
//! slicing that tiles the compiled plan, bit-exactness of 2- and 3-way
//! `ShardChain`s against the single-device `Pipeline` — including
//! residual bypasses, where cuts must snap around the tee..join region —
//! and the measured-vs-analytic steady-state FPS check on compute-bound
//! configurations. The serving tier rides the same machinery through
//! the engine's `BackendKind::Sharded` (DESIGN.md S19).

use lutmul::coordinator::{Coordinator, ServeConfig};
use lutmul::dataflow::multi::{partition, LinkModel};
use lutmul::engine::{BackendKind, Engine};
use lutmul::dataflow::{FoldConfig, Pipeline, ShardChain};
use lutmul::fabric::device::U280;
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::network::{ConvKind, Network, Op};
use lutmul::graph::plan::NetworkPlan;
use lutmul::graph::{mobilenet_v2_small, ArchSpec, LayerSpec};
use lutmul::synth::fold::{optimize_folding, Budget};
use lutmul::util::prop::{self, Rng};

mod common;
use common::{random_images, random_spec};

/// A small network with a residual bypass: conv, tee, two convs, join,
/// strided conv, pool, dense — the shape whose mid-bypass boundaries a
/// shard cut must never split.
fn residual_net(seed: u64) -> Network {
    let spec = ArchSpec {
        name: "res".into(),
        input_hw: 8,
        input_ch: 3,
        layers: vec![
            LayerSpec { name: "c0".into(), kind: ConvKind::Std, cin: 3, cout: 6, k: 3, stride: 1, in_hw: 8, w_bits: 4, a_bits: 4 },
            LayerSpec { name: "c1".into(), kind: ConvKind::Pw, cin: 6, cout: 8, k: 1, stride: 1, in_hw: 8, w_bits: 4, a_bits: 4 },
            LayerSpec { name: "c2".into(), kind: ConvKind::Pw, cin: 8, cout: 6, k: 1, stride: 1, in_hw: 8, w_bits: 4, a_bits: 4 },
            LayerSpec { name: "c3".into(), kind: ConvKind::Std, cin: 6, cout: 5, k: 3, stride: 2, in_hw: 8, w_bits: 4, a_bits: 4 },
            LayerSpec { name: "fc".into(), kind: ConvKind::Pw, cin: 5, cout: 3, k: 1, stride: 1, in_hw: 1, w_bits: 8, a_bits: 8 },
        ],
    };
    let mut net = Network::synthetic(&spec, seed);
    // wrap c1..c2 in a residual bypass: ops are
    // [input, c0, c1, c2, c3, pool, dense] -> insert push before c1 and
    // add after c2 (c1: 6ch -> 8ch -> c2: back to 6ch, so the join widths
    // match)
    net.ops.insert(2, Op::ResPush {});
    net.ops.insert(5, Op::ResAdd { bits: 4 });
    net
}

#[test]
fn prop_partition_contiguous_covering_and_finite() {
    prop::cases(12, |rng| {
        let spec = random_spec(rng);
        let folds: Vec<usize> =
            spec.layers.iter().map(|_| 1 + rng.below(4) as usize).collect();
        let max_devices = spec.layers.len().min(4);
        for n in 1..=max_devices {
            let plan = partition(&spec, &U280, n, &folds, LinkModel::gbe100());
            // respects the device count (layer granularity can merge)
            assert!(!plan.partitions.is_empty() && plan.partitions.len() <= n);
            // contiguous and covering every layer exactly once
            assert_eq!(plan.partitions[0].first_layer, 0);
            assert_eq!(
                plan.partitions.last().unwrap().last_layer,
                spec.layers.len() - 1
            );
            for w in plan.partitions.windows(2) {
                assert_eq!(w[0].last_layer + 1, w[1].first_layer, "contiguous cut");
            }
            for p in &plan.partitions {
                assert!(p.first_layer <= p.last_layer);
                assert!(p.bound_cycles >= 1);
            }
            let fps = plan.fps();
            assert!(fps.is_finite() && fps > 0.0, "fps {fps}");
            assert!(plan.compute_fps() >= fps && plan.link_fps() >= fps);
        }
    });
}

#[test]
fn prop_analytic_partition_lowers_to_executable_shards() {
    prop::cases(8, |rng| {
        let spec = random_spec(rng);
        let folds = vec![1usize; spec.layers.len()];
        let net = Network::synthetic(&spec, rng.next_u64());
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        for n in [1usize, 2, 3] {
            let mplan = partition(&spec, &U280, n, &folds, LinkModel::gbe100());
            let shards = mplan.to_shards(&plan).unwrap();
            assert!(!shards.is_empty() && shards.len() <= n);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, plan.ops.len());
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start, "shards tile the plan");
                assert_eq!(
                    (w[0].out_pixels, w[0].out_ch),
                    (w[1].in_pixels, w[1].in_ch),
                    "geometry chains across the cut"
                );
            }
            let convs: usize = shards.iter().map(|s| s.plan.n_convs()).sum();
            assert_eq!(convs, plan.n_convs(), "every conv placed exactly once");
        }
    });
}

#[test]
fn prop_shard_chain_bit_exact_with_single_pipeline() {
    // the equivalence acceptance: 2- and 3-way chains reproduce the
    // single-device pipeline exactly on randomized synthetic networks
    prop::cases(6, |rng| {
        let spec = random_spec(rng);
        let net = Network::synthetic(&spec, rng.next_u64());
        let images = random_images(rng, &net, 3);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let folds = FoldConfig::fully_parallel(plan.n_convs());
        let want = Pipeline::from_plan(&plan, &folds, 8).run(&images).unwrap();
        for n in [2usize, 3] {
            let shards = plan.shard_evenly(n);
            let mut chain =
                ShardChain::new(&shards, &folds, 8, &LinkModel::gbe100(), 333.0, 4)
                    .unwrap();
            let got = chain.run(&images).unwrap();
            assert_eq!(
                got.logits, want.logits,
                "{n}-way chain diverged (hw={})",
                net.meta.image_size
            );
            assert!(got.image_done_cycles.windows(2).all(|w| w[0] < w[1]));
            assert_eq!(got.shards.len(), shards.len());
            assert_eq!(got.links.len(), shards.len() - 1);
        }
    });
}

#[test]
fn shard_chain_snaps_cuts_around_residual_bypasses() {
    let net = residual_net(0xE5);
    let images = {
        let mut rng = Rng::new(77);
        random_images(&mut rng, &net, 4)
    };
    let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
    let folds = FoldConfig::fully_parallel(plan.n_convs());
    let want = Pipeline::from_plan(&plan, &folds, 8).run(&images).unwrap();
    // mid-bypass boundaries are not valid cuts
    let cuts = plan.cut_points();
    for b in 3..=5usize {
        assert!(!cuts.contains(&b), "boundary {b} splits the bypass");
    }
    for n in [2usize, 3] {
        let shards = plan.shard_evenly(n);
        // the bypass never straddles a shard boundary
        for s in &shards {
            let pushes = s
                .plan
                .ops
                .iter()
                .filter(|op| matches!(op, lutmul::graph::plan::PlanOp::ResPush { .. }))
                .count();
            let adds = s
                .plan
                .ops
                .iter()
                .filter(|op| matches!(op, lutmul::graph::plan::PlanOp::ResAdd { .. }))
                .count();
            assert_eq!(pushes, adds, "shard {}..{} splits a bypass", s.start, s.end);
        }
        let mut chain =
            ShardChain::new(&shards, &folds, 8, &LinkModel::gbe100(), 333.0, 4).unwrap();
        let got = chain.run(&images).unwrap();
        assert_eq!(got.logits, want.logits, "{n}-way residual chain");
    }
}

#[test]
fn measured_chain_fps_tracks_analytic_model_when_compute_bound() {
    // the acceptance bound: on compute-bound configurations the simulated
    // steady-state FPS lands within 15% of MultiFpgaPlan::fps()
    let arch = mobilenet_v2_small();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    let net = Network::synthetic(&arch, 0x5EED);
    let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
    let conv_folds = FoldConfig { folds: folds[..plan.n_convs()].to_vec() };
    let mut rng = Rng::new(11);
    let images = random_images(&mut rng, &net, 10);
    for n in [1usize, 2, 3] {
        let mplan = partition(&arch, &U280, n, &folds, LinkModel::gbe100());
        assert!(!mplan.is_link_bound(), "100 GbE never binds the small net");
        let shards = mplan.to_shards(&plan).unwrap();
        let mut chain = ShardChain::new(
            &shards,
            &conv_folds,
            16,
            &LinkModel::gbe100(),
            U280.max_freq_mhz,
            4,
        )
        .unwrap();
        let rep = chain.run(&images).unwrap();
        let measured = rep.measured_steady_fps(U280.max_freq_mhz);
        let modeled = mplan.fps();
        let ratio = measured / modeled;
        assert!(
            (0.85..=1.15).contains(&ratio),
            "{n} device(s): measured {measured:.0} FPS vs modeled {modeled:.0} FPS (ratio {ratio:.3})"
        );
    }
}

#[test]
fn slow_links_throttle_the_executable_chain_too() {
    // the analytic model says a thin link caps FPS; the executable chain
    // must show the same throttling (tokens pace at cycles_per_token)
    let arch = mobilenet_v2_small();
    let folds = vec![1usize; arch.layers.len()];
    let net = Network::synthetic(&arch, 0xBEEF);
    let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
    let conv_folds = FoldConfig::fully_parallel(plan.n_convs());
    let mut rng = Rng::new(23);
    let images = random_images(&mut rng, &net, 6);
    let fast_link = LinkModel::gbe100();
    let slow_link = LinkModel { bandwidth_bps: 2e8, latency_s: 2e-6 };
    let mplan = partition(&arch, &U280, 2, &folds, slow_link);
    let shards = mplan.to_shards(&plan).unwrap();
    let run_with = |link: &LinkModel, images: &[Vec<i32>]| {
        let mut chain =
            ShardChain::new(&shards, &conv_folds, 16, link, U280.max_freq_mhz, 4).unwrap();
        chain.run(images).unwrap()
    };
    let fast = run_with(&fast_link, &images);
    let slow = run_with(&slow_link, &images);
    assert_eq!(fast.logits, slow.logits, "link speed never changes results");
    assert!(
        slow.incremental_cycles_per_image() > fast.incremental_cycles_per_image(),
        "thin link must stretch the steady-state interval: {} !> {}",
        slow.incremental_cycles_per_image(),
        fast.incremental_cycles_per_image()
    );
    assert!(slow.links[0].cycles_per_token > fast.links[0].cycles_per_token);
}

#[test]
fn sharded_backend_serves_bit_exact_with_shard_metrics() {
    // BackendKind::Sharded end to end through the coordinator: results
    // match the reference executor and the metrics expose per-shard
    // counters (workers drive boxed InferenceBackends — there is no
    // backend-specific code left in the coordinator)
    let net = Network::synthetic(&mobilenet_v2_small(), 42);
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let io = net.io();
    let mut rng = Rng::new(99);
    let images = random_images(&mut rng, &net, 8);
    let engine = Engine::builder()
        .network(net)
        .backend(BackendKind::Sharded { devices: 2 })
        .build()
        .unwrap();
    let coord = Coordinator::start(
        &engine,
        ServeConfig { workers: 1, max_batch: 4, ..Default::default() },
    )
    .unwrap();
    let tickets: Vec<_> = images
        .iter()
        .map(|img| coord.submit(img.clone()).expect("queue accepts"))
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        let want =
            ex.execute(&Tensor::from_hwc(io.image_size, io.image_size, io.in_ch, images[i].clone()));
        assert_eq!(r.logits, want, "request {i}");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, 8);
    assert_eq!(m.shards.len(), 2, "two shards report occupancy");
    assert!(m.shards.iter().all(|s| s.fires > 0), "both shards fired");
    assert!(m.shards[0].link_busy_cycles > 0, "tokens crossed the link");
    assert!(m.to_string().contains("shard0"), "{m}");
    coord.shutdown();
}
