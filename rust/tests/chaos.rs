//! Chaos suite for the serving tier (DESIGN.md S21): inject worker
//! failures, overload a real socket, and throw malformed bytes at the
//! server — the invariants are that every in-flight request resolves to
//! a structured outcome (nothing vanishes), the `rejected` counter is
//! driven by genuine backpressure from a live socket, connections
//! survive malformed-but-framed requests, and the cumulative metrics
//! never roll backwards.
//!
//! Backends are injected through `Coordinator::start_with` (the seam the
//! coordinator exposes for exactly this), so failures are deterministic:
//! `fail_next` arms N batch failures, `slow_ms` turns the worker into a
//! bottleneck.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use lutmul::coordinator::{Coordinator, MetricsSummary, RequestClass, ServeConfig, ServeError};
use lutmul::engine::{BackendFactory, BatchOutput, InferenceBackend};
use lutmul::serve::proto::{self, RequestFrame, Status};
use lutmul::serve::{Server, ServerConfig};

/// Codes per image for the fake backend (no real network needed — the
/// chaos suite tests the serving machinery, not the math).
const IMAGE_PX: usize = 4;

/// Shared control block for every backend the factory builds, across
/// rebuilds.
#[derive(Default)]
struct Control {
    builds: AtomicU64,
    calls: AtomicU64,
    /// Fail this many upcoming batches (decremented per failure).
    fail_next: AtomicU64,
    /// Sleep this long per batch (worker bottleneck for overload tests).
    slow_ms: AtomicU64,
}

/// Deterministic fake backend: logits are a pure function of the image,
/// so results stay verifiable through failures and rebuilds.
struct FlakyBackend {
    ctl: Arc<Control>,
}

fn expected_logits(img: &[i32]) -> Vec<f32> {
    vec![img.iter().sum::<i32>() as f32, img[0] as f32, 0.5]
}

impl InferenceBackend for FlakyBackend {
    fn name(&self) -> &str {
        "flaky"
    }

    fn infer_batch(&mut self, images: &[Vec<i32>]) -> anyhow::Result<BatchOutput> {
        self.ctl.calls.fetch_add(1, Ordering::SeqCst);
        let armed = self
            .ctl
            .fail_next
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1));
        if armed.is_ok() {
            anyhow::bail!("injected backend fault");
        }
        let slow = self.ctl.slow_ms.load(Ordering::Relaxed);
        if slow > 0 {
            std::thread::sleep(Duration::from_millis(slow));
        }
        Ok(BatchOutput {
            logits: images.iter().map(|i| expected_logits(i)).collect(),
            cycles: 0,
            counters: Vec::new(),
        })
    }
}

fn flaky_factory(ctl: Arc<Control>) -> BackendFactory {
    Arc::new(move || {
        ctl.builds.fetch_add(1, Ordering::SeqCst);
        Ok(Box::new(FlakyBackend { ctl: ctl.clone() }))
    })
}

fn img(seed: i32) -> Vec<i32> {
    (0..IMAGE_PX as i32).map(|i| (seed + i) & 15).collect()
}

/// The cumulative counters a summary must never decrease.
fn assert_monotonic(prev: &MetricsSummary, next: &MetricsSummary, label: &str) {
    assert!(next.completed >= prev.completed, "{label}: completed rolled back");
    assert!(next.batches >= prev.batches, "{label}: batches rolled back");
    assert!(next.failed >= prev.failed, "{label}: failed rolled back");
    assert!(next.shed_deadline >= prev.shed_deadline, "{label}: shed rolled back");
    assert!(next.rejected >= prev.rejected, "{label}: rejected rolled back");
}

#[test]
fn worker_failure_resolves_every_ticket_and_rebuilds() {
    let ctl = Arc::new(Control::default());
    let coord = Coordinator::start_with(
        flaky_factory(ctl.clone()),
        IMAGE_PX,
        1_000,
        ServeConfig {
            workers: 1,
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            queue_depth: 64,
        },
    )
    .unwrap();
    assert_eq!(ctl.builds.load(Ordering::SeqCst), 1, "one eager backend build");

    // arm one batch failure, then submit a batch: every ticket must
    // resolve — some to WorkerFailed (the poisoned batch), the rest (if
    // the batcher split the burst) to correct results from the rebuilt
    // backend
    ctl.fail_next.store(1, Ordering::SeqCst);
    let images: Vec<Vec<i32>> = (0..4).map(img).collect();
    let tickets: Vec<_> =
        images.iter().map(|i| coord.submit(i.clone()).unwrap()).collect();
    let mut failed = 0u64;
    let mut completed = 0u64;
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Err(ServeError::WorkerFailed(msg)) => {
                assert!(msg.contains("injected"), "unexpected failure: {msg}");
                failed += 1;
            }
            Ok(r) => {
                assert_eq!(r.logits, expected_logits(&images[i]), "request {i}");
                completed += 1;
            }
            other => panic!("ticket {i} resolved to {other:?}"),
        }
    }
    assert_eq!(failed + completed, 4, "a ticket vanished");
    assert!(failed >= 1, "the armed fault never fired");
    let m1 = coord.metrics();
    assert_eq!(m1.failed, failed);
    assert_eq!(m1.completed, completed);
    assert!(
        ctl.builds.load(Ordering::SeqCst) >= 2,
        "the worker never rebuilt through the factory"
    );

    // the rebuilt backend serves correct results
    let after = coord.submit(img(9)).unwrap().wait().unwrap();
    assert_eq!(after.logits, expected_logits(&img(9)));
    let m2 = coord.metrics();
    assert_eq!(m2.completed, completed + 1);
    assert_monotonic(&m1, &m2, "after rebuild");
    coord.shutdown();
}

#[test]
fn socket_flood_drives_rejected_with_every_request_answered() {
    // a slow single worker + a tiny queue: an open-loop flood from a
    // real socket must bounce at admission (Status::Rejected on the
    // wire, the coordinator's `rejected` counter climbing) while every
    // frame still gets exactly one in-order response
    let ctl = Arc::new(Control::default());
    ctl.slow_ms.store(30, Ordering::Relaxed);
    let coord = Coordinator::start_with(
        flaky_factory(ctl),
        IMAGE_PX,
        1_000,
        ServeConfig {
            workers: 1,
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            queue_depth: 2,
        },
    )
    .unwrap();
    let server = Server::over(coord, ServerConfig::default()).unwrap();

    const FLOOD: u64 = 40;
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    for id in 0..FLOOD {
        let codes: Vec<u8> = img(id as i32).iter().map(|&c| c as u8).collect();
        let frame = proto::encode_request(&RequestFrame {
            id,
            deadline_us: 0,
            class: RequestClass::Latency,
            codes,
        });
        proto::write_frame(&mut w, &frame).unwrap();
    }
    w.flush().unwrap();

    let mut r = BufReader::new(&stream);
    let (mut ok, mut rejected) = (0u64, 0u64);
    for id in 0..FLOOD {
        let payload = proto::read_frame(&mut r, None).unwrap().expect("response missing");
        let resp = proto::decode_response(&payload).unwrap();
        assert_eq!(resp.id, id, "responses reordered under overload");
        match resp.status {
            Status::Ok => {
                assert_eq!(resp.logits, expected_logits(&img(id as i32)));
                ok += 1;
            }
            Status::Rejected => rejected += 1,
            other => panic!("request {id}: unexpected status {other:?}"),
        }
    }
    assert_eq!(ok + rejected, FLOOD, "a request vanished under overload");
    assert!(ok >= 1, "nothing completed");
    assert!(rejected >= 1, "the flood never hit admission control");
    assert_eq!(server.rejected(), rejected, "wire statuses vs rejected counter");
    let m = server.metrics();
    assert_eq!(m.completed, ok);
    assert_eq!(m.rejected, rejected);
    drop(r);
    drop(w);
    drop(stream);
    server.shutdown();
}

#[test]
fn malformed_frames_answer_without_killing_connection_or_server() {
    let ctl = Arc::new(Control::default());
    let coord = Coordinator::start_with(
        flaky_factory(ctl),
        IMAGE_PX,
        1_000,
        ServeConfig::default(),
    )
    .unwrap();
    let server = Server::over(coord, ServerConfig::default()).unwrap();
    let addr = server.local_addr();

    let send_valid = |w: &mut dyn Write, id: u64| {
        let codes: Vec<u8> = img(id as i32).iter().map(|&c| c as u8).collect();
        let frame = proto::encode_request(&RequestFrame {
            id,
            deadline_us: 0,
            class: RequestClass::Latency,
            codes,
        });
        proto::write_frame(w, &frame).unwrap();
        w.flush().unwrap();
    };
    let read_one = |r: &mut dyn Read| -> proto::ResponseFrame {
        let payload = proto::read_frame(r, None).unwrap().expect("closed early");
        proto::decode_response(&payload).unwrap()
    };

    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(&stream);

    // healthy request
    send_valid(&mut w, 1);
    let resp = read_one(&mut r);
    assert_eq!((resp.id, resp.status), (1, Status::Ok));

    // bad version byte: structurally invalid, framing intact — answered
    // Malformed, connection survives
    let mut bad = proto::encode_request(&RequestFrame {
        id: 2,
        deadline_us: 0,
        class: RequestClass::Latency,
        codes: vec![1; IMAGE_PX],
    });
    bad[4] = 99; // corrupt the version byte inside the payload
    w.write_all(&bad).unwrap();
    w.flush().unwrap();
    let resp = read_one(&mut r);
    assert_eq!(resp.status, Status::Malformed);

    // wrong code count: decodes fine, bounced by shape admission —
    // Malformed with the request's own id, connection survives
    send_valid(&mut w, 3); // keep ordering observable
    let codes = vec![1u8; IMAGE_PX + 3];
    let frame = proto::encode_request(&RequestFrame {
        id: 4,
        deadline_us: 0,
        class: RequestClass::Latency,
        codes,
    });
    w.write_all(&frame).unwrap();
    w.flush().unwrap();
    let resp = read_one(&mut r);
    assert_eq!((resp.id, resp.status), (3, Status::Ok));
    let resp = read_one(&mut r);
    assert_eq!((resp.id, resp.status), (4, Status::Malformed));

    // torn framing: a length prefix far over MAX_FRAME cannot be
    // resynchronized — the server answers Malformed and closes
    w.write_all(&u32::MAX.to_le_bytes()).unwrap();
    w.flush().unwrap();
    let resp = read_one(&mut r);
    assert_eq!(resp.status, Status::Malformed);
    let eof = proto::read_frame(&mut r, None).unwrap();
    assert!(eof.is_none(), "server must close after a framing error");
    drop(r);
    drop(w);
    drop(stream);

    // the server itself is unharmed: a fresh connection still serves
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(&stream);
    send_valid(&mut w, 7);
    let resp = read_one(&mut r);
    assert_eq!((resp.id, resp.status), (7, Status::Ok));
    drop(r);
    drop(w);
    drop(stream);

    assert!(
        server.stats().malformed.load(Ordering::Relaxed) >= 3,
        "malformed traffic was not counted"
    );
    server.shutdown();
}

#[test]
fn metrics_stay_monotonic_through_failures_sheds_and_rejects() {
    let ctl = Arc::new(Control::default());
    let coord = Coordinator::start_with(
        flaky_factory(ctl.clone()),
        IMAGE_PX,
        1_000,
        ServeConfig {
            workers: 1,
            max_batch: 2,
            max_wait: Duration::from_millis(2),
            queue_depth: 64,
        },
    )
    .unwrap();

    // phase 1: healthy traffic
    for i in 0..4 {
        coord.submit(img(i)).unwrap().wait().unwrap();
    }
    let m1 = coord.metrics();
    assert_eq!(m1.completed, 4);

    // phase 2: injected failure
    ctl.fail_next.store(1, Ordering::SeqCst);
    let t = coord.submit(img(5)).unwrap();
    assert!(matches!(t.wait(), Err(ServeError::WorkerFailed(_))));
    let m2 = coord.metrics();
    assert_monotonic(&m1, &m2, "after failure");
    assert!(m2.failed >= 1);

    // phase 3: deadline shed
    let t = coord.try_submit(img(6), Some(Duration::ZERO)).unwrap();
    assert!(matches!(t.wait(), Err(ServeError::DeadlineExceeded { .. })));
    let m3 = coord.metrics();
    assert_monotonic(&m2, &m3, "after shed");
    assert!(m3.shed_deadline >= 1);

    // phase 4: healthy again — the rebuilt backend and the histograms
    // keep accumulating
    for i in 0..3 {
        coord.submit(img(10 + i)).unwrap().wait().unwrap();
    }
    let m4 = coord.metrics();
    assert_monotonic(&m3, &m4, "after recovery");
    assert_eq!(m4.completed, 7);
    assert_eq!(m4.failed, 1);
    assert_eq!(m4.shed_deadline, 1);
    coord.shutdown();
}
