//! Integration tests across modules: artifacts -> graph -> executor ->
//! dataflow -> coordinator, plus property sweeps over the fabric and
//! folding invariants. Requires `make artifacts` (skips gracefully if the
//! artifacts are missing so `cargo test` works on a fresh checkout).

use lutmul::coordinator::{argmax, Coordinator, ServeConfig};
use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::engine::{BackendKind, Engine};
use lutmul::fabric::lutmul::ConstMultiplier;
use lutmul::graph::executor::{decode_test_images, Datapath, Executor, Tensor};
use lutmul::graph::network::Network;
use lutmul::runtime::Artifacts;
use lutmul::synth::fold::{optimize_folding, Budget};
use lutmul::util::prop;

fn artifacts() -> Option<(Network, Vec<Vec<i32>>, Vec<u8>)> {
    let a = Artifacts::new("artifacts");
    let net = Network::load(a.network_json()).ok()?;
    let (images, labels) =
        a.load_test_set(net.meta.image_size, net.meta.image_size, net.meta.in_ch).ok()?;
    Some((net, images, labels))
}

#[test]
fn trained_network_loads_and_validates() {
    let Some((net, images, labels)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert_eq!(net.meta.image_size, 16);
    assert_eq!(net.convs().count(), 14);
    assert_eq!(images.len(), labels.len());
    assert!(images.len() >= 256);
    assert!(net.validate().is_ok());
}

#[test]
fn executor_matches_golden_logits() {
    // aot.py embeds the JAX golden logits for the first 32 test images;
    // the reference executor must reproduce them bit-for-bit.
    let Some((net, images, _)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert!(!net.meta.golden_logits.is_empty(), "export includes golden logits");
    let ex = Executor::new(&net, Datapath::Arithmetic);
    for (i, want) in net.meta.golden_logits.iter().enumerate() {
        let t = Tensor::from_hwc(16, 16, 3, images[i].clone());
        let got = ex.execute(&t);
        // integer path is bit-exact; the final dense f32 op may differ by
        // <=2 ULP vs jax-python (FMA vs mul+add — see util::float)
        assert!(
            lutmul::util::slices_ulp_eq(&got, want, 2),
            "image {i} logits diverge from JAX golden: {got:?} vs {want:?}"
        );
    }
}

#[test]
fn dataflow_pipeline_matches_executor_on_trained_net() {
    let Some((net, images, _)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let n = 12;
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let mut pipe = Pipeline::build(&net, &FoldConfig::fully_parallel(net.convs().count()), 16);
    let rep = pipe.run(&images[..n]).unwrap();
    for i in 0..n {
        let t = Tensor::from_hwc(16, 16, 3, images[i].clone());
        assert_eq!(rep.logits[i], ex.execute(&t), "image {i}");
    }
    // the pipeline is input-streaming bound: 256 pixels/image
    assert_eq!(rep.steady_state_cycles_per_image, 256);
}

#[test]
fn lut_fabric_datapath_bit_exact_on_trained_net() {
    // every 4-bit multiplication in the net done by LUT6_2 readout
    let Some((net, images, _)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let a = Executor::new(&net, Datapath::Arithmetic);
    let b = Executor::new(&net, Datapath::LutFabric);
    for img in images.iter().take(4) {
        let t = Tensor::from_hwc(16, 16, 3, img.clone());
        assert_eq!(a.execute(&t), b.execute(&t));
    }
}

#[test]
fn deployed_accuracy_matches_export() {
    let Some((net, images, labels)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let correct = images
        .iter()
        .zip(&labels)
        .filter(|(img, &y)| {
            let t = Tensor::from_hwc(16, 16, 3, (*img).clone());
            argmax(&ex.execute(&t)) == y as usize
        })
        .count();
    let acc = correct as f64 / images.len() as f64;
    // aot.py recorded the deployed accuracy at export time
    assert!(
        (acc - net.meta.acc_int).abs() < 1e-9,
        "rust accuracy {acc} != exported {}",
        net.meta.acc_int
    );
}

#[test]
fn coordinator_serves_correct_results() {
    let Some((net, images, _labels)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::builder()
        .network(net.clone())
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let coord = Coordinator::start(
        &engine,
        ServeConfig { workers: 2, max_batch: 4, ..Default::default() },
    )
    .unwrap();
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let n = 24;
    let tickets: Vec<_> =
        (0..n).map(|i| coord.submit(images[i].clone()).expect("queue accepts")).collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        let want = ex.execute(&Tensor::from_hwc(16, 16, 3, images[i].clone()));
        assert_eq!(r.logits, want, "request {i}");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, n as u64);
    assert!(m.p99_us >= m.p50_us);
    coord.shutdown();
}

#[test]
fn coordinator_batches_requests() {
    let Some((net, images, _)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = Engine::builder().network(net).build().unwrap();
    let coord = Coordinator::start(
        &engine,
        ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(5),
            ..Default::default()
        },
    )
    .unwrap();
    // fire a burst; all must complete despite a single worker
    let tickets: Vec<_> =
        (0..64).map(|i| coord.submit(images[i % images.len()].clone()).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(coord.metrics().completed, 64);
    coord.shutdown();
}

#[test]
fn engine_backends_agree_on_trained_net() {
    let Some((net, images, _)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let imgs = &images[..3];
    let mut engine = Engine::builder()
        .network(net)
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let a = engine.infer_batch(imgs).unwrap().logits;
    let b = engine
        .make_backend(BackendKind::Pipeline)
        .unwrap()
        .infer_batch(imgs)
        .unwrap()
        .logits;
    assert_eq!(a, b);
    // the sharded chain (2 simulated devices over links) agrees too —
    // on the trained net this exercises residual-balanced cut snapping
    let c = engine
        .make_backend(BackendKind::Sharded { devices: 2 })
        .unwrap()
        .infer_batch(imgs)
        .unwrap()
        .logits;
    assert_eq!(a, c);
}

#[test]
fn decode_test_images_roundtrip() {
    let Some((net, images, _)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let bytes = std::fs::read("artifacts/test_images.bin").unwrap();
    let tensors = decode_test_images(&bytes, net.meta.image_size, net.meta.in_ch);
    assert_eq!(tensors.len(), images.len());
    assert_eq!(tensors[0].data, images[0]);
}

// ---------------------------------------------------------------------------
// Property sweeps (deterministic seeds; no proptest in the vendored set)
// ---------------------------------------------------------------------------

#[test]
fn prop_lut_multiplier_exact_for_all_bitwidths() {
    prop::cases(200, |rng| {
        let bits = *rng.choose(&[1u32, 2, 3, 4]);
        let lim = 1i32 << (bits - 1);
        let w0 = rng.range_i32(-lim, lim - 1);
        let w1 = rng.range_i32(-lim, lim - 1);
        let m = ConstMultiplier::new(w0, w1, bits);
        let a = rng.range_i32(0, (1 << bits) - 1) as u32;
        assert_eq!(m.eval(false, a), w0 * a as i32);
        assert_eq!(m.eval(true, a), w1 * a as i32);
    });
}

#[test]
fn prop_multithreshold_monotone_in_acc() {
    use lutmul::quant::MultiThreshold;
    prop::cases(100, |rng| {
        let levels = (1 << rng.range_i32(1, 4)) - 1;
        let base = rng.range_i32(-50, 50);
        let step = rng.range_i32(1, 9);
        let thresholds = vec![(0..levels).map(|i| base + i * step).collect::<Vec<_>>()];
        let sign = *rng.choose(&[1i32, -1]);
        let mt = MultiThreshold { thresholds, signs: vec![sign], consts: vec![0] };
        let mut prev = mt.apply(-200, 0);
        for acc in -199..200 {
            let cur = mt.apply(acc, 0);
            if sign > 0 {
                assert!(cur >= prev, "positive gain must be monotone increasing");
            } else {
                assert!(cur <= prev, "negative gain must be monotone decreasing");
            }
            assert!((0..=levels).contains(&cur));
            prev = cur;
        }
    });
}

#[test]
fn prop_folding_never_changes_results() {
    // random small networks: any fold assignment produces identical logits
    use lutmul::graph::network::{ConvKind, Meta, Op};
    prop::cases(12, |rng| {
        let cin = rng.range_i32(1, 4) as usize;
        let cout = rng.range_i32(1, 6) as usize;
        let k = *rng.choose(&[1usize, 3]);
        let cols = k * k * cin;
        let net = Network {
            meta: Meta {
                image_size: 6,
                in_ch: cin,
                num_classes: 2,
                in_scale: 1.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops: vec![
                Op::Input { bits: 4, scale: 1.0 },
                Op::Conv {
                    name: "c".into(),
                    kind: if k == 1 { ConvKind::Pw } else { ConvKind::Std },
                    cin,
                    cout,
                    k,
                    stride: 1,
                    pad: (k - 1) / 2,
                    w_bits: 4,
                    in_bits: 4,
                    out_bits: 4,
                    w_codes: (0..cout).map(|_| rng.vec_i32(cols, -8, 7)).collect(),
                    thresholds: (0..cout)
                        .map(|_| {
                            let b = rng.range_i32(-20, 20);
                            let s = rng.range_i32(1, 4);
                            (0..15).map(|i| b + i * s).collect()
                        })
                        .collect(),
                    signs: vec![1; cout],
                    consts: vec![0; cout],
                    out_scale: 0.1,
                },
                Op::PoolSum {},
                Op::Dense {
                    name: "fc".into(),
                    cin: cout,
                    cout: 2,
                    w_bits: 8,
                    w_codes: (0..cout).map(|_| rng.vec_i32(2, -128, 127)).collect(),
                    scale: vec![0.01, 0.01],
                    bias: vec![0.0, 0.0],
                },
            ],
        };
        let images: Vec<Vec<i32>> = (0..2).map(|_| rng.vec_i32(36 * cin, 0, 15)).collect();
        let fold = rng.range_i32(1, 6) as usize;
        let a = Pipeline::build(&net, &FoldConfig::fully_parallel(1), 8).run(&images).unwrap();
        let b = Pipeline::build(&net, &FoldConfig::uniform(1, fold), 8).run(&images).unwrap();
        assert_eq!(a.logits, b.logits);
    });
}

#[test]
fn prop_fold_optimizer_feasible_and_balanced() {
    use lutmul::graph::mobilenet_v2_full;
    let arch = mobilenet_v2_full();
    for denom in [1u64, 2, 4, 16] {
        let budget = Budget::fraction(&lutmul::fabric::device::U280, denom);
        let (folds, cycles) = optimize_folding(&arch, &budget);
        // every layer respects the throughput target
        for (l, &f) in arch.layers.iter().zip(&folds) {
            let out_px = (l.out_hw() * l.out_hw()) as u64;
            assert!(out_px * f as u64 <= cycles.max(out_px), "{}", l.name);
        }
    }
}

#[test]
fn netlist_roundtrip_parses_back_to_products() {
    // emit Verilog for a trained layer, scrape the INIT vectors back out,
    // evaluate them as LUT6_2s, and check they compute the weight products
    let Some((net, _, _)) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    use lutmul::fabric::lut::Lut6_2;
    let Some(lutmul::graph::network::Op::Conv { name, w_codes, .. }) = net
        .ops
        .iter()
        .find(|op| matches!(op, lutmul::graph::network::Op::Conv { w_bits: 4, .. }))
    else {
        panic!("no 4-bit conv in trained net");
    };
    let v = lutmul::fabric::netlist::emit_layer(name, w_codes, 4);

    // scrape module bodies: name + 4 INIT constants each
    let mut modules: Vec<(String, Vec<u64>)> = Vec::new();
    let mut cur: Option<(String, Vec<u64>)> = None;
    for line in v.lines() {
        if let Some(rest) = line.strip_prefix("module ") {
            let mname = rest.split(' ').next().unwrap().to_string();
            if mname.contains("_mul_") {
                cur = Some((mname, Vec::new()));
            }
        } else if let Some((_, inits)) = cur.as_mut() {
            if let Some(pos) = line.find("64'h") {
                let hex: String = line[pos + 4..]
                    .chars()
                    .take_while(|c| c.is_ascii_hexdigit() || *c == '_')
                    .filter(|c| *c != '_')
                    .collect();
                inits.push(u64::from_str_radix(&hex, 16).unwrap());
            }
            if line.starts_with("endmodule") {
                modules.push(cur.take().unwrap());
            }
        }
    }
    assert!(!modules.is_empty());
    for (mname, inits) in &modules {
        assert_eq!(inits.len(), 4, "{mname}");
        // decode the embedded weights from the module name: l_mul_{w0}_{w1}
        let parts: Vec<&str> = mname.rsplitn(3, '_').collect(); // [w1, w0, rest]
        let dec = |s: &str| -> i32 {
            if let Some(n) = s.strip_prefix('n') { -n.parse::<i32>().unwrap() } else { s.parse().unwrap() }
        };
        let (w1, w0) = (dec(parts[0]), dec(parts[1]));
        let luts: Vec<Lut6_2> = inits.iter().map(|&i| Lut6_2::new(i)).collect();
        let eval = |ws: bool, a: u8| -> i32 {
            let addr5 = ((ws as u8) << 4) | a;
            let mut p = 0u32;
            for (l, lut) in luts.iter().enumerate() {
                let (o6, o5) = lut.eval_dual(addr5);
                if o6 { p |= 1 << (7 - 2 * l); }
                if o5 { p |= 1 << (6 - 2 * l); }
            }
            ((p << 24) as i32) >> 24
        };
        for a in 0..16u8 {
            assert_eq!(eval(false, a), w0 * a as i32, "{mname} ws=0 a={a}");
            assert_eq!(eval(true, a), w1 * a as i32, "{mname} ws=1 a={a}");
        }
    }
}

#[test]
fn multi_fpga_partition_of_trained_small_net() {
    use lutmul::dataflow::multi::{partition, LinkModel};
    use lutmul::fabric::device::U280;
    use lutmul::graph::mobilenet_v2_small;
    use lutmul::synth::fold::{optimize_folding, Budget};
    let arch = mobilenet_v2_small();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    for n in [1usize, 2] {
        let plan = partition(&arch, &U280, n, &folds, LinkModel::gbe100());
        assert_eq!(plan.partitions.len(), n);
        assert!(plan.fps() > 0.0);
    }
}
