//! Arena-reuse properties (DESIGN.md S20, no artifacts needed): on
//! randomized synthetic networks, running images through a deliberately
//! **dirtied** `Scratch`/`ScratchPool` must be bit-exact with the
//! fresh-allocation path (`Executor::execute`, which builds a new arena
//! per call) and with the per-MAC LUT6_2 readout baseline
//! (`NetworkPlan::compile_direct`) — across both datapaths and both
//! memoized table layouts. Leftover state in a reused arena must never
//! leak into a result.

mod common;

use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::network::{Network, Op};
use lutmul::graph::plan::NetworkPlan;
use lutmul::graph::{Scratch, ScratchPool};
use lutmul::util::prop::{self, Rng};

fn tensors_for(rng: &mut Rng, net: &Network, n: usize) -> Vec<Tensor> {
    let (s, c) = (net.meta.image_size, net.meta.in_ch);
    common::random_images(rng, net, n)
        .into_iter()
        .map(|d| Tensor::from_hwc(s, s, c, d))
        .collect()
}

#[test]
fn prop_dirty_arena_matches_fresh_allocation_and_direct_readout() {
    prop::cases(8, |rng| {
        let spec = common::random_spec(rng);
        let net = Network::synthetic(&spec, rng.next_u64());
        let tensors = tensors_for(rng, &net, 3);
        for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
            let ex = Executor::new(&net, dp);
            // fresh-allocation reference: a new arena per call
            let want: Vec<Vec<f32>> = tensors.iter().map(|t| ex.execute(t)).collect();

            // one poisoned arena reused across every image
            let nc = ex.plan().dense_cout().expect("dense head");
            let mut scratch = Scratch::for_plan(ex.plan());
            let mut logits = vec![f32::NAN; nc];
            for (t, w) in tensors.iter().zip(&want) {
                scratch.dirty(rng.range_i32(-9, 9));
                ex.execute_into(t, &mut scratch, &mut logits);
                assert_eq!(&logits, w, "dirty Scratch ({dp:?}, hw={})", net.meta.image_size);
            }

            // poisoned pool through the batch path, 1 and 3 threads
            let mut pool = ScratchPool::new();
            let mut out = Vec::new();
            for threads in [1usize, 3] {
                pool.dirty(-5);
                ex.run_batch_into(&tensors, threads, &mut pool, &mut out);
                assert_eq!(out, want, "dirty pool, {threads} threads ({dp:?})");
            }

            // independent witnesses: per-MAC readout and the MAC-major
            // table layout, fresh arenas
            let direct = Executor::from_plan(NetworkPlan::compile_direct(&net, dp));
            let mac = Executor::from_plan(NetworkPlan::compile_mac_major(&net, dp));
            for (t, w) in tensors.iter().zip(&want) {
                assert_eq!(&direct.execute(t), w, "compile_direct ({dp:?})");
                assert_eq!(&mac.execute(t), w, "compile_mac_major ({dp:?})");
            }
        }
    });
}

#[test]
fn dirty_arena_handles_residual_state() {
    // residual bypass slots live in the arena; a poisoned slot must not
    // leak into the join
    let mut rng = Rng::new(0xA3E4A);
    let spec = common::random_spec(&mut rng);
    let mut net = Network::synthetic(&spec, 77);
    // wrap a shape-preserving conv (cin == cout, stride 1) in a
    // residual block — push before it, join after it — so the bypass
    // slot actually carries a feature map; specs without such a conv
    // just run residual-free
    let wrap = net.ops.iter().position(|op| {
        matches!(op, Op::Conv { cin, cout, stride, .. } if cin == cout && *stride == 1)
    });
    if let Some(i) = wrap {
        net.ops.insert(i, Op::ResPush {});
        net.ops.insert(i + 2, Op::ResAdd { bits: 4 });
    }
    let ex = Executor::new(&net, Datapath::LutFabric);
    let tensors = tensors_for(&mut rng, &net, 4);
    let want: Vec<Vec<f32>> = tensors.iter().map(|t| ex.execute(t)).collect();
    let mut pool = ScratchPool::new();
    let mut out = Vec::new();
    pool.ensure(1, ex.plan());
    pool.dirty(13);
    ex.run_batch_into(&tensors, 1, &mut pool, &mut out);
    assert_eq!(out, want);
}

#[test]
fn one_arena_serves_differently_shaped_plans() {
    // ensure() is grow-only: the same Scratch must serve a small plan
    // after a big one and vice versa, bit-exactly
    let mut rng = Rng::new(0x5CA1E);
    let (spec_a, spec_b) = (common::random_spec(&mut rng), common::random_spec(&mut rng));
    let net_a = Network::synthetic(&spec_a, 1);
    let net_b = Network::synthetic(&spec_b, 2);
    let (ex_a, ex_b) =
        (Executor::new(&net_a, Datapath::LutFabric), Executor::new(&net_b, Datapath::LutFabric));
    let ta = tensors_for(&mut rng, &net_a, 2);
    let tb = tensors_for(&mut rng, &net_b, 2);
    let mut scratch = Scratch::new();
    for _ in 0..2 {
        for (ex, ts) in [(&ex_a, &ta), (&ex_b, &tb)] {
            let nc = ex.plan().dense_cout().unwrap();
            let mut logits = vec![0.0f32; nc];
            for t in ts.iter() {
                scratch.dirty(-3);
                ex.execute_into(t, &mut scratch, &mut logits);
                assert_eq!(logits, ex.execute(t));
            }
        }
    }
}
