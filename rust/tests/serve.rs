//! Serving-tier property suite (DESIGN.md S21, no artifacts needed —
//! synthetic networks on trained shapes):
//!
//!  * randomized concurrent submitters through the coordinator: every
//!    ticket resolves to the logits of *its own* image (no reordering,
//!    no cross-wiring), bit-identical to a direct `Executor` run;
//!  * the TCP binary protocol round-trips logits bit-exactly, answers
//!    pipelined frames in submission order, and keeps connections
//!    isolated from each other;
//!  * batches close both ways — window timeout and `max_batch` fill —
//!    with zero lost requests either way;
//!  * expired deadlines are shed before compute with the shed count in
//!    `MetricsSummary`, in-process and across the wire;
//!  * the HTTP/1.1 fallback answers `POST /infer`, `GET /metrics` and
//!    `GET /healthz` on the same port as the binary protocol.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use lutmul::coordinator::{Coordinator, RequestClass, ServeConfig, ServeError};
use lutmul::engine::{BackendKind, Engine};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::Network;
use lutmul::serve::proto::{self, RequestFrame, Status};
use lutmul::serve::{Server, ServerConfig};
use lutmul::util::prop::{self, Rng};

fn small_net() -> Network {
    Network::synthetic(&mobilenet_v2_small(), 0x17)
}

fn random_images(rng: &mut Rng, net: &Network, n: usize) -> Vec<Vec<i32>> {
    let (s, c) = (net.meta.image_size, net.meta.in_ch);
    (0..n).map(|_| rng.vec_i32(s * s * c, 0, 15)).collect()
}

/// Direct (coordinator-free) logits for `images` — the ground truth
/// every serving path must reproduce bit-for-bit.
fn direct_logits(net: &Network, images: &[Vec<i32>]) -> Vec<Vec<f32>> {
    let (s, c) = (net.meta.image_size, net.meta.in_ch);
    let ex = Executor::new(net, Datapath::Arithmetic);
    let tensors: Vec<Tensor> =
        images.iter().map(|i| Tensor::from_hwc(s, s, c, i.clone())).collect();
    ex.run_batch(&tensors)
}

fn engine_over(net: &Network) -> Engine {
    Engine::builder()
        .network(net.clone())
        .backend(BackendKind::Reference)
        .build()
        .unwrap()
}

/// Put one request frame on the wire (latency class — these suites
/// exercise the single-pool coordinator; fleet routing lives in
/// `tests/fleet.rs`).
fn send_req(w: &mut impl Write, id: u64, deadline_us: u32, image: &[i32]) {
    let codes: Vec<u8> = image.iter().map(|&c| c as u8).collect();
    let frame = proto::encode_request(&RequestFrame {
        id,
        deadline_us,
        class: RequestClass::Latency,
        codes,
    });
    proto::write_frame(w, &frame).unwrap();
    w.flush().unwrap();
}

/// Read one response frame off the wire.
fn read_resp(r: &mut impl Read) -> proto::ResponseFrame {
    let payload = proto::read_frame(r, None).unwrap().expect("connection closed early");
    proto::decode_response(&payload).unwrap()
}

#[test]
fn prop_concurrent_submits_no_reorder_no_cross_wire() {
    // randomized concurrent submitters: whatever the batcher interleaves,
    // each ticket must resolve to its own image's logits, bit-identical
    // to the direct executor run
    prop::cases(4, |rng| {
        let net = small_net();
        let engine = engine_over(&net);
        let n_threads = 2 + rng.below(3) as usize;
        let per_thread = 3 + rng.below(6) as usize;
        let coord = Coordinator::start(
            &engine,
            ServeConfig {
                workers: 2,
                max_batch: 1 + rng.below(8) as usize,
                max_wait: Duration::from_micros(200),
                ..Default::default()
            },
        )
        .unwrap();

        let images: Vec<Vec<Vec<i32>>> =
            (0..n_threads).map(|_| random_images(rng, &net, per_thread)).collect();
        let want: Vec<Vec<Vec<f32>>> =
            images.iter().map(|imgs| direct_logits(&net, imgs)).collect();

        std::thread::scope(|s| {
            for (imgs, want) in images.iter().zip(&want) {
                let coord = &coord;
                s.spawn(move || {
                    // submit everything first (concurrent pressure on the
                    // batch window), then wait in submission order
                    let tickets: Vec<_> = imgs
                        .iter()
                        .map(|img| coord.submit(img.clone()).expect("queue accepts"))
                        .collect();
                    for (i, t) in tickets.into_iter().enumerate() {
                        let r = t.wait().expect("request resolves");
                        assert_eq!(r.logits, want[i], "request {i} got another image's logits");
                    }
                });
            }
        });

        let m = coord.metrics();
        assert_eq!(m.completed as usize, n_threads * per_thread);
        assert_eq!(m.shed_deadline, 0);
        assert_eq!(m.failed, 0);
        coord.shutdown();
    });
}

#[test]
fn socket_binary_round_trip_bit_exact_in_order() {
    // pipelined frames over one socket: responses come back in
    // submission order with logits bit-identical to the direct executor
    // (f32 bits survive the wire)
    let net = small_net();
    let engine = engine_over(&net);
    let server =
        Server::start(&engine, ServeConfig::default(), ServerConfig::default()).unwrap();

    let mut rng = Rng::new(0xB17);
    let images = random_images(&mut rng, &net, 12);
    let want = direct_logits(&net, &images);

    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    for (i, img) in images.iter().enumerate() {
        send_req(&mut w, 1000 + i as u64, 0, img);
    }
    let mut r = BufReader::new(&stream);
    for (i, want) in want.iter().enumerate() {
        let resp = read_resp(&mut r);
        assert_eq!(resp.id, 1000 + i as u64, "response out of order");
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(&resp.logits, want, "logits not bit-exact across the wire");
    }
    drop(r);
    drop(w);
    drop(stream);

    let m = server.metrics();
    assert_eq!(m.completed, 12);
    server.shutdown();
}

#[test]
fn socket_connections_are_isolated() {
    // several client connections at once: each sees exactly its own
    // responses, in its own submission order
    let net = small_net();
    let engine = engine_over(&net);
    let server = Server::start(
        &engine,
        ServeConfig { workers: 2, max_batch: 4, ..Default::default() },
        ServerConfig::default(),
    )
    .unwrap();
    let addr = server.local_addr();

    let mut rng = Rng::new(0x150);
    let clients: Vec<Vec<Vec<i32>>> = (0..3).map(|_| random_images(&mut rng, &net, 6)).collect();
    let wants: Vec<Vec<Vec<f32>>> = clients.iter().map(|c| direct_logits(&net, c)).collect();

    std::thread::scope(|s| {
        for (ci, (imgs, want)) in clients.iter().zip(&wants).enumerate() {
            s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut w = BufWriter::new(stream.try_clone().unwrap());
                for (i, img) in imgs.iter().enumerate() {
                    send_req(&mut w, ((ci as u64) << 32) | i as u64, 0, img);
                }
                let mut r = BufReader::new(&stream);
                for (i, want) in want.iter().enumerate() {
                    let resp = read_resp(&mut r);
                    assert_eq!(resp.id, ((ci as u64) << 32) | i as u64, "client {ci} crossed wires");
                    assert_eq!(resp.status, Status::Ok);
                    assert_eq!(&resp.logits, want, "client {ci} request {i}");
                }
            });
        }
    });

    assert_eq!(server.metrics().completed, 18);
    server.shutdown();
}

#[test]
fn timeout_close_and_fill_close_lose_nothing() {
    // both batch-close paths: a partial batch flushed by the window
    // timeout, and a full batch closed by max_batch — every ticket
    // resolves either way
    let net = small_net();
    let engine = engine_over(&net);
    let coord = Coordinator::start(
        &engine,
        ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        },
    )
    .unwrap();
    let mut rng = Rng::new(0xC105E);

    // timeout close: 3 < max_batch, the window must flush them
    let imgs = random_images(&mut rng, &net, 3);
    let want = direct_logits(&net, &imgs);
    let tickets: Vec<_> = imgs.iter().map(|i| coord.submit(i.clone()).unwrap()).collect();
    for (t, want) in tickets.into_iter().zip(&want) {
        assert_eq!(&t.wait().unwrap().logits, want);
    }

    // fill close: exactly max_batch in one burst
    let imgs = random_images(&mut rng, &net, 8);
    let want = direct_logits(&net, &imgs);
    let tickets: Vec<_> = imgs.iter().map(|i| coord.submit(i.clone()).unwrap()).collect();
    for (t, want) in tickets.into_iter().zip(&want) {
        assert_eq!(&t.wait().unwrap().logits, want);
    }

    let m = coord.metrics();
    assert_eq!(m.completed, 11, "a request was lost");
    coord.shutdown();
}

#[test]
fn expired_deadlines_shed_before_compute() {
    // an already-expired deadline must come back DeadlineExceeded (shed
    // at dispatch, before any backend cycles), and the shed count must
    // reach the metrics; a deadline-free request on the same coordinator
    // still completes
    let net = small_net();
    let engine = engine_over(&net);
    let coord = Coordinator::start(&engine, ServeConfig::default()).unwrap();
    let mut rng = Rng::new(0xDEAD);
    let imgs = random_images(&mut rng, &net, 3);

    let shed = coord.try_submit(imgs[0].clone(), Some(Duration::ZERO)).unwrap();
    match shed.wait() {
        Err(ServeError::DeadlineExceeded { .. }) => {}
        other => panic!("expected a deadline shed, got {other:?}"),
    }

    let ok = coord.submit(imgs[1].clone()).unwrap();
    assert_eq!(ok.wait().unwrap().logits, direct_logits(&net, &imgs[1..2])[0]);

    let m = coord.metrics();
    assert_eq!(m.shed_deadline, 1);
    assert_eq!(m.completed, 1);
    // shed requests must not contaminate the latency histograms
    assert_eq!(m.failed, 0);
    coord.shutdown();
}

#[test]
fn wire_deadline_comes_back_as_status() {
    // a 1 us relative deadline has always expired by the time the batch
    // window dispatches; the client must see DeadlineExceeded, not a
    // hang or a dropped connection
    let net = small_net();
    let engine = engine_over(&net);
    let server =
        Server::start(&engine, ServeConfig::default(), ServerConfig::default()).unwrap();
    let mut rng = Rng::new(0xD1);
    let imgs = random_images(&mut rng, &net, 2);

    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    send_req(&mut w, 1, 1, &imgs[0]); // 1 us: dead on arrival
    send_req(&mut w, 2, 0, &imgs[1]); // no deadline: must complete
    let mut r = BufReader::new(&stream);
    let first = read_resp(&mut r);
    assert_eq!((first.id, first.status), (1, Status::DeadlineExceeded));
    assert!(first.logits.is_empty(), "shed responses carry no logits");
    let second = read_resp(&mut r);
    assert_eq!((second.id, second.status), (2, Status::Ok));
    drop(r);
    drop(w);
    drop(stream);

    let m = server.metrics();
    assert_eq!(m.shed_deadline, 1);
    assert_eq!(m.completed, 1);
    server.shutdown();
}

#[test]
fn http_fallback_shares_the_port() {
    let net = small_net();
    let engine = engine_over(&net);
    let server =
        Server::start(&engine, ServeConfig::default(), ServerConfig::default()).unwrap();
    let addr = server.local_addr();
    let mut rng = Rng::new(0x477);
    let img = random_images(&mut rng, &net, 1).remove(0);
    let want = direct_logits(&net, std::slice::from_ref(&img)).remove(0);

    // one-shot HTTP exchange (the server answers with Connection: close)
    let http = |req: String| -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };

    let health = http("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n".into());
    assert!(health.starts_with("HTTP/1.1 200"), "{health}");
    assert!(health.ends_with("ok"), "{health}");

    let body: Vec<u8> = img.iter().map(|&c| c as u8).collect();
    let req = format!(
        "POST /infer HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    // body is raw bytes; codes 0..=15 are not valid UTF-8 text, so build
    // the request manually
    let mut raw = req.into_bytes();
    raw.extend_from_slice(&body);
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&raw).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    assert!(out.starts_with("HTTP/1.1 200"), "{out}");
    let class = lutmul::coordinator::argmax(&want);
    assert!(
        out.contains(&format!("\"class\":{class}")),
        "HTTP response disagrees with the direct executor: {out}"
    );

    let metrics = http("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n".into());
    assert!(metrics.contains("rejected"), "{metrics}");

    let missing = http("GET /nope HTTP/1.1\r\nHost: x\r\n\r\n".into());
    assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

    assert!(server.metrics().completed >= 1);
    server.shutdown();
}
