//! Batch-major execution tests (no artifacts needed — synthetic networks
//! on trained shapes, see EXPERIMENTS.md "Test triage"):
//!
//!  * `Executor::run_batch` must be bit-exact against N independent
//!    `execute` calls on both executor datapaths and against the dataflow
//!    pipeline simulator — the serving backends behind the engine's
//!    uniform `InferenceBackend` contract (DESIGN.md S19);
//!  * a full `max_batch` dispatch through the coordinator must return
//!    per-request results in submission order.

use lutmul::coordinator::{Coordinator, ServeConfig};
use lutmul::engine::{BackendKind, Engine};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::{ConvKind, Meta, Network, Op};
use lutmul::util::prop::{self, Rng};

fn small_net() -> Network {
    Network::synthetic(&mobilenet_v2_small(), 0x17)
}

fn random_images(rng: &mut Rng, n: usize, size: usize, ch: usize) -> Vec<Vec<i32>> {
    (0..n).map(|_| rng.vec_i32(size * size * ch, 0, 15)).collect()
}

fn tensors(net: &Network, images: &[Vec<i32>]) -> Vec<Tensor> {
    let s = net.meta.image_size;
    let c = net.meta.in_ch;
    images.iter().map(|i| Tensor::from_hwc(s, s, c, i.clone())).collect()
}

/// Small random network with a residual block (the synthetic MobileNet
/// spec carries no residuals, so batch-state handling is covered here).
fn random_res_net(rng: &mut Rng) -> Network {
    let thr = |rng: &mut Rng, cout: usize| -> Vec<Vec<i32>> {
        (0..cout)
            .map(|_| {
                let base = rng.range_i32(-20, 20);
                let step = rng.range_i32(1, 5);
                (0..15).map(|i| base + i * step).collect()
            })
            .collect()
    };
    #[allow(clippy::too_many_arguments)]
    let conv = |rng: &mut Rng,
                name: &str,
                kind: ConvKind,
                cin: usize,
                cout: usize,
                k: usize,
                stride: usize| {
        let cols = if kind == ConvKind::Dw { k * k } else { k * k * cin };
        Op::Conv {
            name: name.into(),
            kind,
            cin,
            cout,
            k,
            stride,
            pad: (k - 1) / 2,
            w_bits: 4,
            in_bits: 4,
            out_bits: 4,
            w_codes: (0..cout).map(|_| rng.vec_i32(cols, -8, 7)).collect(),
            thresholds: thr(rng, cout),
            signs: vec![1; cout],
            consts: vec![0; cout],
            out_scale: 0.1,
        }
    };
    let mut ops = vec![Op::Input { bits: 4, scale: 1.0 / 15.0 }];
    ops.push(conv(rng, "c0", ConvKind::Std, 3, 6, 3, 1));
    ops.push(Op::ResPush {});
    ops.push(conv(rng, "c1", ConvKind::Pw, 6, 8, 1, 1));
    ops.push(conv(rng, "c2", ConvKind::Dw, 8, 8, 3, 1));
    ops.push(conv(rng, "c3", ConvKind::Pw, 8, 6, 1, 1));
    ops.push(Op::ResAdd { bits: 4 });
    ops.push(Op::PoolSum {});
    ops.push(Op::Dense {
        name: "fc".into(),
        cin: 6,
        cout: 3,
        w_bits: 8,
        w_codes: (0..6).map(|_| rng.vec_i32(3, -128, 127)).collect(),
        scale: vec![0.01; 3],
        bias: vec![0.5, -0.5, 0.0],
    });
    Network {
        meta: Meta {
            image_size: 8,
            in_ch: 3,
            num_classes: 3,
            in_scale: 1.0 / 15.0,
            w_bits: 4,
            a_bits: 4,
            acc_int: 0.0,
            n_test: 0,
            golden_logits: vec![],
        },
        ops,
    }
}

#[test]
fn prop_run_batch_bit_exact_vs_sequential_both_datapaths() {
    prop::cases(8, |rng| {
        let net = random_res_net(rng);
        let n = 1 + rng.below(6) as usize;
        let imgs = tensors(&net, &random_images(rng, n, 8, 3));
        for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
            let ex = Executor::new(&net, dp);
            let batch = ex.run_batch(&imgs);
            let seq: Vec<Vec<f32>> = imgs.iter().map(|t| ex.execute(t)).collect();
            assert_eq!(batch, seq, "{dp:?} batch {n}");
        }
    });
}

#[test]
fn run_batch_bit_exact_on_mobilenet_shape() {
    // trained-network shape; odd batch size exercises uneven thread chunks
    let net = small_net();
    let mut rng = Rng::new(42);
    let imgs = tensors(&net, &random_images(&mut rng, 9, 16, 3));
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let batch = ex.run_batch(&imgs);
    assert_eq!(batch.len(), 9);
    for (i, t) in imgs.iter().enumerate() {
        assert_eq!(batch[i], ex.execute(t), "image {i}");
    }
}

#[test]
fn run_batch_edge_sizes() {
    let net = small_net();
    let mut rng = Rng::new(7);
    let imgs = tensors(&net, &random_images(&mut rng, 2, 16, 3));
    let ex = Executor::new(&net, Datapath::Arithmetic);
    assert!(ex.run_batch(&[]).is_empty());
    assert_eq!(ex.run_batch(&imgs[..1]), vec![ex.execute(&imgs[0])]);
}

#[test]
fn all_engine_backends_agree_on_batches() {
    // the server-level batch API: the reference executor, the LUT-fabric
    // datapath and the batch-pipelined simulator must produce identical
    // logits through the uniform InferenceBackend contract
    let net = small_net();
    let mut rng = Rng::new(3);
    let images = random_images(&mut rng, 4, 16, 3);
    let mut engine = Engine::builder()
        .network(net.clone())
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let a = engine.infer_batch(&images).unwrap().logits;
    let mut lut = Engine::builder()
        .network(net)
        .datapath(Datapath::LutFabric)
        .build()
        .unwrap();
    let b = lut.infer_batch(&images).unwrap().logits;
    let c = engine
        .make_backend(BackendKind::Pipeline)
        .unwrap()
        .infer_batch(&images)
        .unwrap()
        .logits;
    assert_eq!(a, b, "Reference vs LutFabric");
    assert_eq!(a, c, "Reference vs Simulator");
    // the multi-device chain is the fourth face of the same plans
    let d = engine
        .make_backend(BackendKind::Sharded { devices: 2 })
        .unwrap()
        .infer_batch(&images)
        .unwrap()
        .logits;
    assert_eq!(a, d, "Reference vs Sharded");
}

#[test]
fn coordinator_full_batch_returns_submission_order() {
    // one worker, one full max_batch dispatch: every ticket must resolve
    // to the logits of the image submitted with it, in submission order
    let net = small_net();
    let mut rng = Rng::new(11);
    let images = random_images(&mut rng, 8, 16, 3);
    let engine = Engine::builder()
        .network(net.clone())
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let coord = Coordinator::start(
        &engine,
        ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(50),
            ..Default::default()
        },
    )
    .unwrap();
    let tickets: Vec<_> =
        images.iter().map(|img| coord.submit(img.clone()).expect("queue accepts")).collect();
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let want = tensors(&net, &images);
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_eq!(r.logits, ex.execute(&want[i]), "request {i} out of order");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, 8);
    assert!(m.batches >= 1 && m.batches <= 8, "batches {}", m.batches);
    assert!(m.mean_batch >= 1.0);
    coord.shutdown();
}

#[test]
fn coordinator_batches_on_simulator_backend() {
    // the batch-pipelined simulator serves correct results under batching
    let net = small_net();
    let mut rng = Rng::new(5);
    let images = random_images(&mut rng, 6, 16, 3);
    let engine = Engine::builder()
        .network(net.clone())
        .backend(BackendKind::Pipeline)
        .build()
        .unwrap();
    let coord = Coordinator::start(
        &engine,
        ServeConfig {
            workers: 1,
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(20),
            ..Default::default()
        },
    )
    .unwrap();
    let tickets: Vec<_> = images.iter().map(|img| coord.submit(img.clone()).unwrap()).collect();
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let want = tensors(&net, &images);
    for (i, t) in tickets.into_iter().enumerate() {
        assert_eq!(t.wait().unwrap().logits, ex.execute(&want[i]), "request {i}");
    }
    coord.shutdown();
}
