//! Plan-compilation equivalence properties (DESIGN.md S17, no artifacts
//! needed): on randomized `Network::synthetic` configs — varying stride,
//! padding, Dw/Pw/Std kinds and odd widths that exercise the
//! interior/border split — the Arithmetic and LutFabric plans must agree
//! bit-for-bit, the memoized LUT product tables must match the per-MAC
//! LUT6_2 readout they were read from, and the dataflow pipeline built
//! from the same plan must reproduce the executor exactly.

use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::network::{ConvKind, Network};
use lutmul::graph::plan::NetworkPlan;
use lutmul::graph::{ArchSpec, LayerSpec};
use lutmul::util::prop::{self, Rng};

/// Random 4-bit conv stack + 8-bit classifier head, in the shape format
/// `Network::synthetic` lowers (SAME padding, pad = (k-1)/2).
fn random_spec(rng: &mut Rng) -> ArchSpec {
    let input_hw = *rng.choose(&[5usize, 7, 9, 11, 16]); // odd widths included
    let input_ch = 1 + rng.below(3) as usize;
    let mut layers = Vec::new();
    let (mut cin, mut hw) = (input_ch, input_hw);
    let n_layers = 2 + rng.below(3) as usize;
    for i in 0..n_layers {
        let kind = *rng.choose(&[ConvKind::Std, ConvKind::Pw, ConvKind::Dw]);
        let (k, stride) = match kind {
            ConvKind::Pw => (1, 1),
            _ => (3, 1 + rng.below(2) as usize),
        };
        let cout = match kind {
            ConvKind::Dw => cin,
            _ => 1 + rng.below(6) as usize,
        };
        layers.push(LayerSpec {
            name: format!("l{i}"),
            kind,
            cin,
            cout,
            k,
            stride,
            in_hw: hw,
            w_bits: 4,
            a_bits: 4,
        });
        hw = hw.div_ceil(stride);
        cin = cout;
    }
    layers.push(LayerSpec {
        name: "fc".into(),
        kind: ConvKind::Pw,
        cin,
        cout: 3,
        k: 1,
        stride: 1,
        in_hw: 1,
        w_bits: 8,
        a_bits: 8,
    });
    ArchSpec { name: "random".into(), input_hw, input_ch, layers }
}

fn random_tensors(rng: &mut Rng, net: &Network, n: usize) -> Vec<Tensor> {
    let (s, c) = (net.meta.image_size, net.meta.in_ch);
    (0..n).map(|_| Tensor::from_hwc(s, s, c, rng.vec_i32(s * s * c, 0, 15))).collect()
}

#[test]
fn prop_datapaths_and_plans_agree_bit_for_bit() {
    prop::cases(10, |rng| {
        let spec = random_spec(rng);
        let net = Network::synthetic(&spec, rng.next_u64());
        let tensors = random_tensors(rng, &net, 3);

        let arith = Executor::new(&net, Datapath::Arithmetic);
        let tables = Executor::new(&net, Datapath::LutFabric);
        let direct = Executor::from_plan(NetworkPlan::compile_direct(&net, Datapath::LutFabric));
        let want: Vec<Vec<f32>> = tensors.iter().map(|t| arith.execute(t)).collect();
        for (t, w) in tensors.iter().zip(&want) {
            assert_eq!(&tables.execute(t), w, "LutFabric tables vs Arithmetic (hw={})", net.meta.image_size);
            assert_eq!(&direct.execute(t), w, "per-MAC LUT readout vs Arithmetic");
        }
        // batch path agrees on both datapaths
        assert_eq!(arith.run_batch(&tensors), want, "Arithmetic run_batch");
        assert_eq!(tables.run_batch(&tensors), want, "LutFabric run_batch");
        // the LUT plans account the same physical fabric
        assert_eq!(tables.plan().lut_count(), direct.plan().lut_count());
        assert!(tables.plan().lut_count() > 0, "4-bit layers must map to LUTs");
    });
}

#[test]
fn prop_pipeline_from_plan_matches_executor() {
    prop::cases(6, |rng| {
        let spec = random_spec(rng);
        let net = Network::synthetic(&spec, rng.next_u64());
        let tensors = random_tensors(rng, &net, 2);
        let images: Vec<Vec<i32>> = tensors.iter().map(|t| t.data.clone()).collect();
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let plan = ex.plan();
        let mut pipe = Pipeline::from_plan(plan, &FoldConfig::fully_parallel(plan.n_convs()), 8);
        let report = pipe.run(&images).unwrap();
        for (got, t) in report.logits.iter().zip(&tensors) {
            assert_eq!(got, &ex.execute(t), "pipeline vs executor (hw={})", net.meta.image_size);
        }
    });
}

#[test]
fn border_split_covers_clamped_edges_deterministically() {
    // 5x5 input, stride-2 std conv then stride-2 depthwise: the interior
    // is a single output column/row, everything else is border rim
    let layers = vec![
        LayerSpec {
            name: "s".into(),
            kind: ConvKind::Std,
            cin: 2,
            cout: 4,
            k: 3,
            stride: 2,
            in_hw: 5,
            w_bits: 4,
            a_bits: 4,
        },
        LayerSpec {
            name: "d".into(),
            kind: ConvKind::Dw,
            cin: 4,
            cout: 4,
            k: 3,
            stride: 2,
            in_hw: 3,
            w_bits: 4,
            a_bits: 4,
        },
        LayerSpec {
            name: "fc".into(),
            kind: ConvKind::Pw,
            cin: 4,
            cout: 2,
            k: 1,
            stride: 1,
            in_hw: 1,
            w_bits: 8,
            a_bits: 8,
        },
    ];
    let spec = ArchSpec { name: "edges".into(), input_hw: 5, input_ch: 2, layers };
    let net = Network::synthetic(&spec, 0xED6E5);
    let mut rng = Rng::new(9);
    let tensors = random_tensors(&mut rng, &net, 4);
    let a = Executor::new(&net, Datapath::Arithmetic);
    let b = Executor::new(&net, Datapath::LutFabric);
    for t in &tensors {
        assert_eq!(a.execute(t), b.execute(t));
    }
    assert_eq!(a.run_batch(&tensors), b.run_batch(&tensors));
}
