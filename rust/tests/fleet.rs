//! Chaos + elasticity suite for the heterogeneous fleet (DESIGN.md
//! S25). The invariants under test:
//!
//!  * killing a ShardChain worker mid-batch loses zero requests: every
//!    drained request re-runs on the rebuilt backend, logits stay
//!    bit-identical to a direct `Executor` run, `rebuilds` counts
//!    exactly the injected kill, and shard occupancy stays monotonic
//!    across the rebuild;
//!  * a request drained past its retry budget resolves to the typed
//!    [`ServeError::RetriesExhausted`] — never a hang, never a silent
//!    drop;
//!  * the autoscaler grows a pool under a sustained burst and
//!    drain-then-retires back to the floor once the queue goes idle;
//!  * each [`RequestClass`] routes to its own pool's backend;
//!  * shutdown (fleet or single-pool coordinator) resolves every
//!    admitted ticket even when every worker has died — the regression
//!    for the admission/shutdown race.
//!
//! Deterministic backends are injected through `Fleet::start_with` /
//! `Coordinator::start_with`, mirroring `tests/chaos.rs`; the one
//! real-engine test drives `Fleet::start` over a synthetic network so
//! both backend kinds (executor replicas, sharded chains) serve live.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lutmul::coordinator::{
    Coordinator, Fleet, FleetConfig, MetricsSummary, PoolScale, RequestClass, ServeConfig,
    ServeError, SubmitError,
};
use lutmul::engine::{BackendFactory, BackendKind, BatchOutput, Engine, InferenceBackend};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::Network;
use lutmul::util::prop::Rng;

/// Codes per image for the injected backends (the elasticity tests
/// exercise the pool machinery, not the math).
const IMAGE_PX: usize = 4;

fn img(seed: i32) -> Vec<i32> {
    (0..IMAGE_PX as i32).map(|i| (seed + i) & 15).collect()
}

/// Shared control block for every backend a factory builds, across
/// rebuilds (same shape as the S21 chaos suite's).
#[derive(Default)]
struct Control {
    builds: AtomicU64,
    calls: AtomicU64,
    /// Fail this many upcoming batches (decremented per failure);
    /// `u64::MAX` fails every batch.
    fail_next: AtomicU64,
    /// Sleep this long per batch (a worker bottleneck, so queue depth
    /// builds and the autoscaler has a signal).
    slow_ms: AtomicU64,
    /// Factory calls beyond this many return an error (0 = unlimited):
    /// how the rebuild-permanently-fails path is staged.
    max_builds: AtomicU64,
}

struct FlakyBackend {
    ctl: Arc<Control>,
    /// Logit tag so class-routing is observable: `logits[2]` carries it.
    tag: f32,
}

fn tagged_logits(img: &[i32], tag: f32) -> Vec<f32> {
    vec![img.iter().sum::<i32>() as f32, img[0] as f32, tag]
}

impl InferenceBackend for FlakyBackend {
    fn name(&self) -> &str {
        "flaky"
    }

    fn infer_batch(&mut self, images: &[Vec<i32>]) -> anyhow::Result<BatchOutput> {
        self.ctl.calls.fetch_add(1, Ordering::SeqCst);
        let armed = self
            .ctl
            .fail_next
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1));
        if armed.is_ok() {
            anyhow::bail!("injected backend fault");
        }
        let slow = self.ctl.slow_ms.load(Ordering::Relaxed);
        if slow > 0 {
            std::thread::sleep(Duration::from_millis(slow));
        }
        Ok(BatchOutput {
            logits: images.iter().map(|i| tagged_logits(i, self.tag)).collect(),
            cycles: 0,
            counters: Vec::new(),
        })
    }
}

fn flaky_factory(ctl: Arc<Control>, tag: f32) -> BackendFactory {
    Arc::new(move || {
        let n = ctl.builds.fetch_add(1, Ordering::SeqCst);
        let cap = ctl.max_builds.load(Ordering::SeqCst);
        if cap > 0 && n >= cap {
            anyhow::bail!("injected factory outage (build {n} refused)");
        }
        Ok(Box::new(FlakyBackend { ctl: ctl.clone(), tag }))
    })
}

/// A fleet config with the supervisor effectively quiesced, so tests of
/// the retry/rebuild path see no autoscale noise.
fn quiet_cfg() -> FleetConfig {
    FleetConfig {
        latency: PoolScale { min_workers: 1, max_workers: 1 },
        throughput: PoolScale { min_workers: 1, max_workers: 1 },
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        queue_depth: 256,
        retry_budget: 2,
        rebuild_backoff: Duration::from_micros(200),
        scale_tick: Duration::from_millis(50),
        high_water: 1_000,
        up_ticks: 1_000,
        idle_ticks: 1_000_000,
    }
}

/// The cumulative counters a summary must never decrease.
fn assert_monotonic(prev: &MetricsSummary, next: &MetricsSummary, label: &str) {
    assert!(next.completed >= prev.completed, "{label}: completed rolled back");
    assert!(next.batches >= prev.batches, "{label}: batches rolled back");
    assert!(next.failed >= prev.failed, "{label}: failed rolled back");
    assert!(next.shed_deadline >= prev.shed_deadline, "{label}: shed rolled back");
    assert!(next.rejected >= prev.rejected, "{label}: rejected rolled back");
}

fn shard_fires(s: &MetricsSummary) -> u64 {
    s.shards.iter().map(|c| c.fires).sum()
}

// ---------------------------------------------------------------------
// tentpole acceptance: kill a ShardChain mid-batch on a real engine
// ---------------------------------------------------------------------

#[test]
fn chaos_kill_mid_batch_loses_nothing_on_real_engine() {
    let net = Network::synthetic(&mobilenet_v2_small(), 0x17);
    let (s, c) = (net.meta.image_size, net.meta.in_ch);
    let engine = Engine::builder()
        .network(net.clone())
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let fleet = Fleet::start(&engine, 2, quiet_cfg()).unwrap();

    let mut rng = Rng::new(0xF1EE7);
    let images: Vec<Vec<i32>> = (0..12).map(|_| rng.vec_i32(s * s * c, 0, 15)).collect();
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let want: Vec<Vec<f32>> = ex.run_batch(
        &images.iter().map(|i| Tensor::from_hwc(s, s, c, i.clone())).collect::<Vec<_>>(),
    );

    // warm wave: the throughput pool serves bit-exactly before any chaos
    for (i, image) in images.iter().take(4).enumerate() {
        let r = fleet.infer(image.clone(), RequestClass::Throughput).unwrap();
        assert_eq!(r.logits, want[i], "warm request {i} diverged");
    }
    assert_eq!(fleet.rebuilds(RequestClass::Throughput), 0);
    let before = fleet.class_summary(RequestClass::Throughput).summary;
    let fires_before = shard_fires(&before);
    assert!(fires_before > 0, "sharded occupancy never recorded");

    // kill the chain mid-batch: every drained request must re-run on the
    // rebuilt backend and still match the executor bit-for-bit
    fleet.chaos_kill(RequestClass::Throughput);
    let tickets: Vec<_> = images
        .iter()
        .skip(4)
        .map(|i| fleet.try_submit(i.clone(), None, RequestClass::Throughput).unwrap())
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap_or_else(|e| panic!("request {i} lost to the kill: {e}"));
        assert_eq!(r.logits, want[i + 4], "request {i} diverged after the kill");
    }
    assert_eq!(
        fleet.rebuilds(RequestClass::Throughput),
        1,
        "exactly the injected kill rebuilds"
    );

    // occupancy banked across the rebuild: cumulative fires never shrink
    let after = fleet.class_summary(RequestClass::Throughput).summary;
    assert!(
        shard_fires(&after) >= fires_before,
        "shard occupancy rolled back across the rebuild ({} -> {})",
        fires_before,
        shard_fires(&after)
    );
    assert_monotonic(&before, &after, "throughput pool across chaos");

    // the latency pool is untouched by throughput-class chaos, serves
    // from its own (executor) backend, and both classes report serving
    let lat = fleet.infer(images[0].clone(), RequestClass::Latency).unwrap();
    assert_eq!(lat.logits, want[0], "latency pool diverged");
    assert_eq!(fleet.rebuilds(RequestClass::Latency), 0);
    let summary = fleet.summary();
    let lat_s = summary.class(RequestClass::Latency).unwrap();
    let thr_s = summary.class(RequestClass::Throughput).unwrap();
    assert!(lat_s.summary.completed >= 1 && thr_s.summary.completed >= 12);
    assert_ne!(lat_s.backend, thr_s.backend, "pools share a backend kind");
    assert!(thr_s.retried >= 1, "the killed batch was never drained into retries");
    assert_eq!(
        fleet.metrics().completed,
        lat_s.summary.completed + thr_s.summary.completed,
        "merged metrics disagree with the per-class sums"
    );
    fleet.shutdown();
}

// ---------------------------------------------------------------------
// retry budget
// ---------------------------------------------------------------------

#[test]
fn retry_budget_exhaustion_sheds_typed() {
    let ctl = Arc::new(Control::default());
    ctl.fail_next.store(u64::MAX, Ordering::SeqCst); // every batch fails
    let mut cfg = quiet_cfg();
    cfg.retry_budget = 1;
    let fleet = Fleet::start_with(
        flaky_factory(ctl.clone(), 1.0),
        flaky_factory(ctl.clone(), 2.0),
        IMAGE_PX,
        1_000,
        cfg,
    )
    .unwrap();

    match fleet.try_submit(img(3), None, RequestClass::Latency).unwrap().wait() {
        Err(ServeError::RetriesExhausted { attempts }) => {
            assert_eq!(attempts, 2, "budget 1 = one retry, two failed executions")
        }
        other => panic!("expected RetriesExhausted, got {other:?}"),
    }
    let cs = fleet.class_summary(RequestClass::Latency);
    assert_eq!(cs.retried, 1, "exactly one re-enqueue within budget");
    assert_eq!(cs.shed_retry, 1, "exactly one typed shed");
    assert_eq!(cs.summary.failed, 1, "the shed counts as a failed request");
    assert!(cs.rebuilds >= 1, "failed batches rebuild the backend");

    // the pool survives: heal the backend and it serves again
    ctl.fail_next.store(0, Ordering::SeqCst);
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match fleet.try_submit(img(5), None, RequestClass::Latency).unwrap().wait() {
            Ok(r) => {
                assert_eq!(r.logits, tagged_logits(&img(5), 1.0));
                break;
            }
            Err(ServeError::RetriesExhausted { .. }) if Instant::now() < deadline => {
                // a straggler failure armed before the heal landed
                continue;
            }
            other => panic!("pool never healed: {other:?}"),
        }
    }
    fleet.shutdown();
}

// ---------------------------------------------------------------------
// autoscaling
// ---------------------------------------------------------------------

#[test]
fn autoscale_grows_under_burst_and_retires_to_floor() {
    let ctl = Arc::new(Control::default());
    ctl.slow_ms.store(3, Ordering::Relaxed); // bottleneck => depth builds
    let cfg = FleetConfig {
        latency: PoolScale { min_workers: 1, max_workers: 3 },
        throughput: PoolScale { min_workers: 1, max_workers: 1 },
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_depth: 256,
        retry_budget: 2,
        rebuild_backoff: Duration::from_micros(200),
        scale_tick: Duration::from_millis(1),
        high_water: 2,
        up_ticks: 2,
        idle_ticks: 5,
    };
    let fleet = Fleet::start_with(
        flaky_factory(ctl.clone(), 1.0),
        flaky_factory(ctl.clone(), 2.0),
        IMAGE_PX,
        1_000,
        cfg,
    )
    .unwrap();
    assert_eq!(fleet.workers(RequestClass::Latency), 1);

    // burst: 40 requests at 3ms each against one worker is ~120ms of
    // backlog — the supervisor (1ms tick, 2 hot ticks) must scale up
    let tickets: Vec<_> = (0..40)
        .map(|i| fleet.try_submit(img(i), None, RequestClass::Latency).unwrap())
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap_or_else(|e| panic!("burst request {i} lost: {e}"));
        assert_eq!(r.logits, tagged_logits(&img(i as i32), 1.0), "request {i} cross-wired");
    }
    let cs = fleet.class_summary(RequestClass::Latency);
    assert!(cs.scale_up >= 1, "the burst never triggered a scale-up");
    assert!(cs.spawned >= 2, "no worker beyond the initial one was spawned");
    assert!(
        fleet.workers(RequestClass::Latency) <= 3,
        "autoscaler exceeded max_workers"
    );

    // idle: with the queue empty, retire orders must drain the pool
    // back to min_workers (5 idle ticks at 1ms — poll generously)
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let workers = fleet.workers(RequestClass::Latency);
        let down = fleet.class_summary(RequestClass::Latency).scale_down;
        if workers == 1 && down >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "pool never retired to the floor (workers {workers}, scale_down {down})"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // the shrunk pool still serves
    let r = fleet.infer(img(99), RequestClass::Latency).unwrap();
    assert_eq!(r.logits, tagged_logits(&img(99), 1.0));
    fleet.shutdown();
}

// ---------------------------------------------------------------------
// class routing
// ---------------------------------------------------------------------

#[test]
fn classes_route_to_their_own_pools() {
    // distinguishable backends per class: the logit tag proves which
    // pool served each request
    let lat_ctl = Arc::new(Control::default());
    let thr_ctl = Arc::new(Control::default());
    let fleet = Fleet::start_with(
        flaky_factory(lat_ctl.clone(), 1.0),
        flaky_factory(thr_ctl.clone(), 2.0),
        IMAGE_PX,
        1_000,
        quiet_cfg(),
    )
    .unwrap();

    for i in 0..6 {
        let class = if i % 2 == 0 { RequestClass::Latency } else { RequestClass::Throughput };
        let tag = if class == RequestClass::Latency { 1.0 } else { 2.0 };
        let r = fleet.infer(img(i), class).unwrap();
        assert_eq!(r.logits, tagged_logits(&img(i), tag), "request {i} routed to the wrong pool");
    }
    assert_eq!(fleet.class_summary(RequestClass::Latency).summary.completed, 3);
    assert_eq!(fleet.class_summary(RequestClass::Throughput).summary.completed, 3);
    assert!(lat_ctl.calls.load(Ordering::SeqCst) >= 3);
    assert!(thr_ctl.calls.load(Ordering::SeqCst) >= 3);

    // a misshapen image bounces at admission for either class
    for class in RequestClass::ALL {
        match fleet.try_submit(vec![1; IMAGE_PX + 1], None, class) {
            Err(SubmitError::BadShape { got, want }) => {
                assert_eq!((got, want), (IMAGE_PX + 1, IMAGE_PX))
            }
            other => panic!("bad shape admitted for {class}: {other:?}"),
        }
    }
    fleet.shutdown();
}

// ---------------------------------------------------------------------
// total-loss chaos: every worker dies, nothing hangs
// ---------------------------------------------------------------------

#[test]
fn fleet_resolves_all_tickets_when_rebuild_fails_permanently() {
    // the latency factory builds exactly one (always-failing) backend,
    // then refuses every rebuild/respawn: the pool's only worker dies
    // permanently, and shutdown must still resolve every admitted ticket
    let ctl = Arc::new(Control::default());
    ctl.fail_next.store(u64::MAX, Ordering::SeqCst);
    ctl.max_builds.store(1, Ordering::SeqCst);
    let healthy = Arc::new(Control::default());
    let mut cfg = quiet_cfg();
    cfg.retry_budget = 0; // first failure sheds typed, no re-runs
    let fleet = Fleet::start_with(
        flaky_factory(ctl.clone(), 1.0),
        flaky_factory(healthy.clone(), 2.0),
        IMAGE_PX,
        1_000,
        cfg,
    )
    .unwrap();

    let tickets: Vec<_> = (0..6)
        .map(|i| fleet.try_submit(img(i), None, RequestClass::Latency).unwrap())
        .collect();
    // give the worker time to fail its first batch and exhaust the
    // rebuild backoff (8 refused builds), then tear the fleet down with
    // requests still queued
    std::thread::sleep(Duration::from_millis(100));
    fleet.shutdown();

    let (mut exhausted, mut shutdown) = (0u64, 0u64);
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Err(ServeError::RetriesExhausted { attempts }) => {
                assert_eq!(attempts, 1);
                exhausted += 1;
            }
            Err(ServeError::Shutdown) | Err(ServeError::Disconnected) => shutdown += 1,
            other => panic!("ticket {i} resolved to {other:?} with a dead pool"),
        }
    }
    assert_eq!(exhausted + shutdown, 6, "a ticket vanished with the dead pool");
    assert!(exhausted >= 1, "the armed fault never fired");
    assert!(shutdown >= 1, "queued requests were not drained as Shutdown");
}

// ---------------------------------------------------------------------
// regression: S21 coordinator shutdown/admission race (satellite fix)
// ---------------------------------------------------------------------

#[test]
fn coordinator_resolves_tickets_when_every_worker_dies() {
    // one worker whose backend always fails and whose rebuild is
    // refused: before the fix, requests admitted between `try_submit`
    // and the batcher's dispatch could hang forever once the worker's
    // queue dropped — now they resolve typed and later submissions see
    // `SubmitError::Shutdown`
    let ctl = Arc::new(Control::default());
    ctl.fail_next.store(u64::MAX, Ordering::SeqCst);
    ctl.max_builds.store(1, Ordering::SeqCst);
    let coord = Coordinator::start_with(
        flaky_factory(ctl, 1.0),
        IMAGE_PX,
        1_000,
        ServeConfig {
            workers: 1,
            max_batch: 2,
            max_wait: Duration::from_micros(200),
            queue_depth: 64,
        },
    )
    .unwrap();

    let tickets: Vec<_> = (0..8).map(|i| coord.submit(img(i)).unwrap()).collect();
    let mut outcomes = [0u64; 3]; // [worker_failed, shutdown, disconnected]
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Err(ServeError::WorkerFailed(_)) => outcomes[0] += 1,
            Err(ServeError::Shutdown) => outcomes[1] += 1,
            Err(ServeError::Disconnected) => outcomes[2] += 1,
            other => panic!("ticket {i} resolved to {other:?} with a dead pool"),
        }
    }
    assert_eq!(outcomes.iter().sum::<u64>(), 8, "a ticket hung or vanished");
    assert!(outcomes[0] >= 1, "the armed fault never fired");

    // once the batcher observes the dead pool it exits, and admission
    // itself turns into the typed shutdown error
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match coord.try_submit(img(0), None) {
            Err(SubmitError::Shutdown) => break,
            Ok(t) => {
                // still admitted: the ticket must resolve typed, not hang
                match t.wait() {
                    Err(
                        ServeError::WorkerFailed(_)
                        | ServeError::Shutdown
                        | ServeError::Disconnected,
                    ) => {}
                    other => panic!("late ticket resolved to {other:?}"),
                }
            }
            Err(e) => panic!("unexpected admission outcome: {e:?}"),
        }
        assert!(
            Instant::now() < deadline,
            "admission never surfaced SubmitError::Shutdown"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    coord.shutdown();
}

// ---------------------------------------------------------------------
// graceful shutdown drains queued traffic
// ---------------------------------------------------------------------

#[test]
fn fleet_shutdown_drains_queued_requests() {
    let ctl = Arc::new(Control::default());
    ctl.slow_ms.store(5, Ordering::Relaxed);
    let mut cfg = quiet_cfg();
    cfg.max_batch = 2;
    let fleet = Fleet::start_with(
        flaky_factory(ctl.clone(), 1.0),
        flaky_factory(ctl.clone(), 2.0),
        IMAGE_PX,
        1_000,
        cfg,
    )
    .unwrap();

    // queue more work than one slow worker can have started, then shut
    // down immediately: workers drain the queue before exiting, so every
    // ticket completes (shutdown waits, it does not drop)
    let tickets: Vec<_> = (0..8)
        .map(|i| fleet.try_submit(img(i), None, RequestClass::Latency).unwrap())
        .collect();
    fleet.shutdown();
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t
            .wait()
            .unwrap_or_else(|e| panic!("request {i} dropped by graceful shutdown: {e}"));
        assert_eq!(r.logits, tagged_logits(&img(i as i32), 1.0));
    }
}
