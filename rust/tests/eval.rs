//! Accuracy-harness conformance (DESIGN.md S24 / EXPERIMENTS.md E17):
//! the labeled synthetic set is deterministic and self-consistent, the
//! exact datapaths score 100% against their own labels, the saturated
//! approximate configuration is bit-exact (and therefore also scores
//! 100%), the learned configuration clears a conservative seeded
//! agreement floor, the Pareto JSON schema stays stable for
//! `scripts/bench_regress.py`, and the approximate plan agrees
//! bit-for-bit across the executor and pipeline backends.

use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::eval::{self, ParetoConfig};
use lutmul::graph::plan::{Datapath, NetworkPlan};
use lutmul::graph::{mobilenet_v2_small, ApproxSpec, Executor, Network, Tensor};

fn net() -> Network {
    Network::synthetic(&mobilenet_v2_small(), 0x5EED)
}

#[test]
fn labeled_synthetic_set_is_deterministic() {
    let net = net();
    let (ia, la) = net.synthetic_labeled(8, 0xE7A1);
    let (ib, lb) = net.synthetic_labeled(8, 0xE7A1);
    assert_eq!(ia, ib, "images must be seed-deterministic");
    assert_eq!(la, lb, "labels must be seed-deterministic");
    assert_eq!(ia.len(), 8);
    assert_eq!(la.len(), 8);
    let io = net.io();
    let px = io.image_size * io.image_size * io.in_ch;
    let amax = (1i32 << net.meta.a_bits) - 1;
    assert!(ia.iter().all(|img| img.len() == px));
    assert!(ia.iter().flatten().all(|&v| (0..=amax).contains(&v)));
    assert!(la.iter().all(|&y| (y as usize) < net.meta.num_classes));
    // a different seed draws a different set
    let (ic, _) = net.synthetic_labeled(8, 0xE7A2);
    assert_ne!(ia, ic, "distinct seeds must draw distinct images");
}

#[test]
fn exact_datapaths_score_full_marks_on_their_own_labels() {
    let net = net();
    let (images, labels) = net.synthetic_labeled(6, 3);
    let cfg = ParetoConfig { sparsity: 0.4, full: true, ..ParetoConfig::default() };
    let rows = eval::pareto(&net, &images, &labels, &cfg).unwrap();
    // full front: exact, mac-major, pruned, approx, saturated approx
    assert_eq!(rows.len(), 5);
    for r in &rows {
        assert!(r.images_per_s > 0.0, "{}: no throughput measured", r.backend);
        assert!(r.lut6 > 0, "{}: LUT-fabric plan must cost LUT6", r.backend);
        assert_eq!(r.score.n, 6);
    }
    for exact in ["executor/lut-exact", "executor/lut-mac-major"] {
        let r = rows.iter().find(|r| r.backend == exact).unwrap();
        assert_eq!(r.score.top1, 1.0, "{exact} must reproduce the labeling datapath");
        assert_eq!(r.score.top5, 1.0);
        assert!(!r.approx);
    }
    let pruned = rows.iter().find(|r| r.sparsity > 0.0).unwrap();
    assert_eq!(pruned.backend, "executor/lut-sparse");
    assert!(
        pruned.score.top1 <= 1.0 && pruned.score.top5 >= pruned.score.top1,
        "pruned scores must be a sane pair"
    );
    // the saturated anchor is exact by construction
    let sat = rows.iter().find(|r| r.backend == "executor/lut-approx-sat").unwrap();
    assert!(sat.approx);
    assert_eq!(sat.score.top1, 1.0, "saturated approx must be bit-exact end to end");
    assert_eq!(sat.score.top5, 1.0);
}

#[test]
fn saturated_approx_logits_are_bit_exact() {
    let net = net();
    let io = net.io();
    let (images, _) = net.synthetic_labeled(5, 11);
    let tensors: Vec<Tensor> = images
        .iter()
        .map(|v| Tensor::from_hwc(io.image_size, io.image_size, io.in_ch, v.clone()))
        .collect();
    let exact = Executor::from_plan(NetworkPlan::compile(&net, Datapath::LutFabric));
    let sat = Executor::from_plan(NetworkPlan::compile_approx(
        &net,
        Datapath::LutFabric,
        &ApproxSpec::saturated(),
    ));
    assert_eq!(
        sat.run_batch_with_threads(&tensors, 1),
        exact.run_batch_with_threads(&tensors, 1),
        "saturated approx logits must equal the exact LUT-fabric logits bit-for-bit"
    );
}

#[test]
fn learned_approx_meets_the_seeded_agreement_floor() {
    // The learned default configuration is approximate by design; the
    // gate is a deliberately conservative floor on agreement with the
    // exact model (10-class argmax) — it catches a collapsed datapath,
    // not a mild accuracy regression. The whole path is seeded, so the
    // score is one fixed number, not a flake source.
    let net = net();
    let (images, labels) = net.synthetic_labeled(24, 0xE7A1);
    let rows = eval::pareto(&net, &images, &labels, &ParetoConfig::default()).unwrap();
    let approx = rows.iter().find(|r| r.approx).unwrap();
    assert!(
        approx.score.top1 >= 0.05,
        "learned approx top-1 {} collapsed below the 0.05 sanity floor",
        approx.score.top1
    );
    assert!(approx.score.top5 >= approx.score.top1);
}

#[test]
fn pareto_json_schema_is_stable() {
    let net = net();
    let (images, labels) = net.synthetic_labeled(4, 7);
    let cfg = ParetoConfig { sparsity: 0.5, full: true, ..ParetoConfig::default() };
    let rows = eval::pareto(&net, &images, &labels, &cfg).unwrap();
    let doc = eval::json(&rows, "lutmul eval --pareto --json", "synthetic twin", 4);
    // the top-level shape scripts/bench_regress.py keys on
    for key in ["\"bench\":", "\"source\":", "\"n_images\": 4", "\"rows\": ["] {
        assert!(doc.contains(key), "missing {key} in:\n{doc}");
    }
    // every row carries the bench-compatible fields plus the eval axes
    for key in [
        "\"backend\":",
        "\"datapath\":",
        "\"images_per_s\":",
        "\"ns_per_image\":",
        "\"top1\":",
        "\"top5\":",
        "\"lut6\":",
    ] {
        assert_eq!(
            doc.matches(key).count(),
            rows.len(),
            "every row must carry {key}:\n{doc}"
        );
    }
    // approx rows are tagged, pruned rows carry their sparsity, and
    // dense exact rows omit both (historical-baseline compatibility)
    assert_eq!(doc.matches("\"approx\": true").count(), 2);
    assert_eq!(doc.matches("\"sparsity\": 0.50").count(), 1);
    let exact_line = doc
        .lines()
        .find(|l| l.contains("executor/lut-exact"))
        .expect("exact row present");
    assert!(!exact_line.contains("approx") && !exact_line.contains("sparsity"));
}

#[test]
fn approx_plan_agrees_across_executor_and_pipeline() {
    // Cross-backend bit-identity of the approximate datapath: the
    // executor's batch-major sweeps and the pipeline's per-patch bodies
    // accumulate codebooks in the same order, so their i32 sums — and
    // hence logits — must match exactly.
    let net = net();
    let io = net.io();
    let (images, _) = net.synthetic_labeled(4, 21);
    let plan = NetworkPlan::compile_approx(&net, Datapath::LutFabric, &ApproxSpec::default());
    let folds = FoldConfig::uniform(plan.n_convs(), 1);
    let mut pipe = Pipeline::from_plan(&plan, &folds, 16);
    let report = pipe.run(&images).unwrap();
    let tensors: Vec<Tensor> = images
        .iter()
        .map(|v| Tensor::from_hwc(io.image_size, io.image_size, io.in_ch, v.clone()))
        .collect();
    let ex = Executor::from_plan(plan);
    assert_eq!(
        report.logits,
        ex.run_batch_with_threads(&tensors, 1),
        "pipeline approx logits diverged from the executor"
    );
}
