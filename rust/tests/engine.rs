//! Engine conformance suite (DESIGN.md S19, no artifacts needed).
//!
//! Every `InferenceBackend` the engine constructs — the reference
//! executor, the dataflow pipeline, and 2- and 3-device shard chains —
//! must produce bit-identical logits on randomized synthetic networks,
//! and the `EngineBuilder` error paths (missing artifacts without a
//! synthetic fallback, fold/conv count mismatches, absent network
//! source, PJRT without artifacts) must diagnose loudly instead of
//! defaulting.

use lutmul::coordinator::{Coordinator, ServeConfig};
use lutmul::dataflow::FoldConfig;
use lutmul::engine::{Arch, BackendKind, Engine, Folding, NetworkSource};
use lutmul::fabric::device::U280;
use lutmul::graph::network::Network;
use lutmul::graph::plan::Datapath;
use lutmul::graph::{mobilenet_v2_full, mobilenet_v2_small};
use lutmul::runtime::Artifacts;
use lutmul::synth::fold::Budget;
use lutmul::util::prop::{self, Rng};

mod common;
use common::{random_images, random_spec};

#[test]
fn prop_all_backends_bit_identical_on_random_networks() {
    // the conformance acceptance: executor, pipeline and 2-/3-device
    // shard chains agree bit-for-bit on randomized synthetic networks
    prop::cases(5, |rng| {
        let spec = random_spec(rng);
        let net = Network::synthetic(&spec, rng.next_u64());
        let images = random_images(rng, &net, 3);
        let mut engine = Engine::builder()
            .network(net)
            .backend(BackendKind::Reference)
            .build()
            .unwrap();
        assert_eq!(engine.source(), NetworkSource::Injected);
        let want = engine.infer_batch(&images).unwrap();
        assert_eq!(want.logits.len(), images.len());
        assert_eq!(want.cycles, 0, "the executor has no cycle model");
        assert!(want.counters.is_empty());
        for kind in [
            BackendKind::Pipeline,
            BackendKind::Sharded { devices: 2 },
            BackendKind::Sharded { devices: 3 },
        ] {
            let mut b = engine.make_backend(kind).unwrap();
            let got = b.infer_batch(&images).unwrap();
            assert_eq!(got.logits, want.logits, "{} diverged from the executor", b.name());
            assert!(got.cycles > 0, "{} is cycle-modeled", b.name());
            assert!(b.steady_cycles().is_some(), "{} reports steady cycles", b.name());
        }
    });
}

#[test]
fn both_datapaths_agree_through_the_engine() {
    // the same network compiled for LutFabric must reproduce the
    // arithmetic logits bit-for-bit (the cross-datapath witness the
    // `bench --backends all` table prints)
    let net = Network::synthetic(&mobilenet_v2_small(), 0xD1CE);
    let mut rng = Rng::new(5);
    let images = random_images(&mut rng, &net, 3);
    let mut arith = Engine::builder().network(net.clone()).build().unwrap();
    let mut lut = Engine::builder()
        .network(net)
        .datapath(Datapath::LutFabric)
        .build()
        .unwrap();
    assert_eq!(arith.backend_name(), "executor");
    assert_eq!(lut.backend_name(), "executor/lut-fabric");
    assert_eq!(
        arith.infer_batch(&images).unwrap().logits,
        lut.infer_batch(&images).unwrap().logits
    );
}

#[test]
fn sharded_backend_reports_counters_and_occupancy() {
    let net = Network::synthetic(&mobilenet_v2_small(), 0xCAFE);
    let mut rng = Rng::new(7);
    let images = random_images(&mut rng, &net, 4);
    let mut engine = Engine::builder()
        .network(net)
        .backend(BackendKind::Sharded { devices: 2 })
        .build()
        .unwrap();
    assert!(engine.backend_name().starts_with("sharded"));
    let out = engine.infer_batch(&images).unwrap();
    assert_eq!(out.counters.len(), 2, "one counter record per shard");
    assert!(out.counters.iter().all(|c| c.fires > 0), "both shards fired");
    assert!(out.counters[0].link_busy_cycles > 0, "tokens crossed the link");
    // the trait-level occupancy matches the batch counters (cumulative)
    assert_eq!(engine.backend().shard_occupancy(), out.counters);
}

#[test]
fn backend_factory_builds_independent_equivalent_backends() {
    let net = Network::synthetic(&mobilenet_v2_small(), 0xFAB);
    let mut rng = Rng::new(9);
    let images = random_images(&mut rng, &net, 3);
    let engine = Engine::builder()
        .network(net)
        .backend(BackendKind::Sharded { devices: 2 })
        .build()
        .unwrap();
    let factory = engine.backend_factory(2);
    let mut b1 = factory().unwrap();
    let mut b2 = factory().unwrap();
    let o1 = b1.infer_batch(&images).unwrap();
    let o2 = b2.infer_batch(&images).unwrap();
    assert_eq!(o1.logits, o2.logits, "factory backends are equivalent");
    // independent state: running one twice must not perturb the other
    let o1b = b1.infer_batch(&images).unwrap();
    assert_eq!(o1b.logits, o2.logits);
}

#[test]
fn folding_choices_never_change_logits() {
    let net = Network::synthetic(&mobilenet_v2_small(), 0xF01D);
    let mut rng = Rng::new(13);
    let images = random_images(&mut rng, &net, 2);
    let run = |folding: Folding| {
        let mut e = Engine::builder()
            .network(net.clone())
            .folding(folding)
            .backend(BackendKind::Pipeline)
            .build()
            .unwrap();
        e.infer_batch(&images).unwrap()
    };
    let fast = run(Folding::FullyParallel);
    let slow = run(Folding::Uniform(4));
    let opt = run(Folding::Optimized(Budget::whole(&U280)));
    // an over-long explicit vector (arch-level, head included) truncates
    let explicit = run(Folding::Explicit(FoldConfig { folds: vec![2; 20] }));
    assert_eq!(fast.logits, slow.logits, "uniform folding changed results");
    assert_eq!(fast.logits, opt.logits, "optimized folding changed results");
    assert_eq!(fast.logits, explicit.logits, "explicit folding changed results");
    assert!(slow.cycles > fast.cycles, "fold 4 must be slower");
}

#[test]
fn explicit_fold_vector_too_short_is_loud() {
    let err = Engine::builder()
        .network(Network::synthetic(&mobilenet_v2_small(), 2))
        .folding(Folding::Explicit(FoldConfig { folds: vec![1; 3] }))
        .build()
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("explicit fold vector"), "{msg}");
    assert!(msg.contains("conv layers"), "{msg}");
}

#[test]
fn missing_artifacts_without_synthetic_fallback_is_loud() {
    let a = Artifacts::new("does/not/exist");
    let err = Engine::builder()
        .arch(Arch::Small)
        .artifacts(&a)
        .build()
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("or_synthetic"), "error must name the fallback: {msg}");
    assert!(msg.contains("network.json"), "error must name the missing file: {msg}");
}

#[test]
fn missing_artifacts_with_synthetic_fallback_builds() {
    let a = Artifacts::new("also/not/here");
    let mut engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(&a)
        .or_synthetic(7)
        .backend(BackendKind::Pipeline)
        .build()
        .unwrap();
    assert_eq!(engine.source(), NetworkSource::Synthetic { seed: 7 });
    assert_eq!(engine.source().label(), "synthetic network");
    let images = engine.images(2).unwrap();
    assert_eq!(images.len(), 2);
    let out = engine.infer_batch(&images).unwrap();
    assert_eq!(out.logits.len(), 2);
    // synthetic networks have no ground-truth labels
    assert!(engine.labeled_test_set().is_err());
}

#[test]
fn no_network_source_is_loud() {
    let err = Engine::builder().build().unwrap_err();
    assert!(err.to_string().contains("network source"), "{err}");
}

#[test]
fn fold_conv_count_mismatch_is_loud() {
    // the Small arch's optimizer cannot cover the Full network's conv
    // stages — the builder must refuse instead of slicing past the end
    let err = Engine::builder()
        .arch(Arch::Small)
        .network(Network::synthetic(&mobilenet_v2_full(), 1))
        .folding(Folding::Optimized(Budget::whole(&U280)))
        .build()
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("conv layers"), "{msg}");
    assert!(msg.contains("different model"), "{msg}");
}

#[cfg(not(feature = "xla"))]
#[test]
fn pjrt_backend_without_artifacts_is_loud() {
    let engine = Engine::builder().or_synthetic(3).build().unwrap();
    let err = engine.make_backend(BackendKind::Pjrt { batch: 1 }).unwrap_err();
    assert!(err.to_string().contains("artifact"), "{err}");
    // with a directory configured but no xla feature, the stub runtime's
    // load error surfaces through the same path
    let engine = Engine::builder()
        .artifacts(&Artifacts::new("nope"))
        .or_synthetic(3)
        .build()
        .unwrap();
    let err = engine.make_backend(BackendKind::Pjrt { batch: 1 }).unwrap_err();
    assert!(err.to_string().contains("xla"), "{err}");
}

#[test]
fn executor_backend_rejects_misshapen_images() {
    let mut engine = Engine::builder().or_synthetic(11).build().unwrap();
    let err = engine.infer_batch(&[vec![0i32; 3]]).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("expects"), "error names the expected geometry: {msg}");
}

#[test]
fn sharded_backend_rejects_zero_devices() {
    let engine = Engine::builder().or_synthetic(21).build().unwrap();
    let err = engine.make_backend(BackendKind::Sharded { devices: 0 }).unwrap_err();
    assert!(err.to_string().contains("at least 1 device"), "{err}");
}

#[test]
fn coordinator_bounces_misshapen_images_at_submit() {
    // a malformed request must not reach a worker, where it would fail
    // a whole co-batched dispatch and force a backend rebuild
    let net = Network::synthetic(&mobilenet_v2_small(), 0xBAD);
    let mut rng = Rng::new(17);
    let images = random_images(&mut rng, &net, 2);
    let engine = Engine::builder().network(net).build().unwrap();
    let coord = Coordinator::start(
        &engine,
        ServeConfig { workers: 1, max_batch: 4, ..Default::default() },
    )
    .unwrap();
    let err = coord.submit(vec![0i32; 5]).unwrap_err();
    assert!(err.to_string().contains("expects"), "{err}");
    // well-formed requests still serve after the bounce
    let ticket = coord.submit(images[0].clone()).unwrap();
    assert!(ticket.wait().is_ok());
    coord.shutdown();
}

#[test]
fn backend_kind_labels_are_stable() {
    assert_eq!(BackendKind::Reference.label(), "executor");
    assert_eq!(BackendKind::Pipeline.label(), "pipeline");
    assert_eq!(BackendKind::Sharded { devices: 3 }.label(), "sharded x3");
    assert_eq!(BackendKind::Pjrt { batch: 8 }.label(), "pjrt b8");
}
