//! Steady-state zero-allocation gate (DESIGN.md S20/S22): after the
//! first batch has sized the arenas, `Executor::run_batch_into` (the
//! batch-major sweep) and `Executor::run_image_major_into` (the
//! image-major witness driver) must both perform **zero heap
//! allocations** — not per image, none at all — on the single-thread
//! path. Asserted with a counting global allocator, which is why this
//! test lives alone in its own binary (one `#[test]` fn, run
//! sequentially): any other test thread allocating during a measured
//! window would pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::Network;
use lutmul::graph::ScratchPool;
use lutmul::util::prop::Rng;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicUsize = AtomicUsize::new(0);

/// Delegates to the system allocator, counting every allocation made
/// while the window is open.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_run_batch_makes_zero_allocations() {
    let net = Network::synthetic(&mobilenet_v2_small(), 0xA10C);
    let io = net.io();
    let (s, c) = (io.image_size, io.in_ch);
    let mut rng = Rng::new(4);
    let images: Vec<Tensor> = (0..8)
        .map(|_| Tensor::from_hwc(s, s, c, rng.vec_i32(s * s * c, 0, 15)))
        .collect();
    for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
        let ex = Executor::new(&net, dp);
        let mut pool = ScratchPool::new();
        let mut out = Vec::new();
        // first batch sizes the arenas and the output slots...
        ex.run_batch_into(&images, 1, &mut pool, &mut out);
        let want = out.clone();
        // ...every later batch must reuse them outright
        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        ex.run_batch_into(&images, 1, &mut pool, &mut out);
        COUNTING.store(false, Ordering::SeqCst);
        let n = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            n, 0,
            "steady-state run_batch_into made {n} heap allocations on {dp:?} \
             (expected zero: every buffer lives in the persistent arena)"
        );
        assert_eq!(out, want, "steady-state batch changed its results ({dp:?})");

        // the image-major witness driver shares the same arena pool and
        // must hold the same steady-state guarantee
        ex.run_image_major_into(&images, 1, &mut pool, &mut out);
        assert_eq!(out, want, "image-major witness diverged ({dp:?})");
        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        ex.run_image_major_into(&images, 1, &mut pool, &mut out);
        COUNTING.store(false, Ordering::SeqCst);
        let n = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            n, 0,
            "steady-state run_image_major_into made {n} heap allocations on {dp:?}"
        );
        assert_eq!(out, want, "steady-state image-major batch changed its results ({dp:?})");
    }

    // the Maddness approximate datapath (DESIGN.md S24) adds a per-batch
    // codes arena (`Scratch::codes`); once sized it must hold the same
    // steady-state guarantee through the batch-major sweep
    let plan = lutmul::graph::plan::NetworkPlan::compile_approx(
        &net,
        Datapath::LutFabric,
        &lutmul::graph::ApproxSpec::default(),
    );
    let ex = Executor::from_plan(plan);
    let mut pool = ScratchPool::new();
    let mut out = Vec::new();
    ex.run_batch_into(&images, 1, &mut pool, &mut out);
    let want = out.clone();
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    ex.run_batch_into(&images, 1, &mut pool, &mut out);
    COUNTING.store(false, Ordering::SeqCst);
    let n = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        n, 0,
        "steady-state approx run_batch_into made {n} heap allocations \
         (expected zero: codebook codes live in the persistent arena)"
    );
    assert_eq!(out, want, "steady-state approx batch changed its results");
}
