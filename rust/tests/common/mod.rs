//! Shared generators for the integration suites (`tests/multi.rs`,
//! `tests/engine.rs`): randomized network shape specs and input images.
//! One copy, so the conformance and sharding suites always test the
//! same network distribution.

use lutmul::graph::network::{ConvKind, Network};
use lutmul::graph::{ArchSpec, LayerSpec};
use lutmul::util::prop::Rng;

/// Random 4-bit conv stack + 8-bit classifier head (the shape format
/// `Network::synthetic` lowers).
pub fn random_spec(rng: &mut Rng) -> ArchSpec {
    let input_hw = *rng.choose(&[5usize, 7, 9, 11, 16]);
    let input_ch = 1 + rng.below(3) as usize;
    let mut layers = Vec::new();
    let (mut cin, mut hw) = (input_ch, input_hw);
    let n_layers = 3 + rng.below(3) as usize;
    for i in 0..n_layers {
        let kind = *rng.choose(&[ConvKind::Std, ConvKind::Pw, ConvKind::Dw]);
        let (k, stride) = match kind {
            ConvKind::Pw => (1, 1),
            _ => (3, 1 + rng.below(2) as usize),
        };
        let cout = match kind {
            ConvKind::Dw => cin,
            _ => 1 + rng.below(6) as usize,
        };
        layers.push(LayerSpec {
            name: format!("l{i}"),
            kind,
            cin,
            cout,
            k,
            stride,
            in_hw: hw,
            w_bits: 4,
            a_bits: 4,
        });
        hw = hw.div_ceil(stride);
        cin = cout;
    }
    layers.push(LayerSpec {
        name: "fc".into(),
        kind: ConvKind::Pw,
        cin,
        cout: 3,
        k: 1,
        stride: 1,
        in_hw: 1,
        w_bits: 8,
        a_bits: 8,
    });
    ArchSpec { name: "random".into(), input_hw, input_ch, layers }
}

/// `n` random input images sized for `net`'s input geometry.
pub fn random_images(rng: &mut Rng, net: &Network, n: usize) -> Vec<Vec<i32>> {
    let (s, c) = (net.meta.image_size, net.meta.in_ch);
    (0..n).map(|_| rng.vec_i32(s * s * c, 0, 15)).collect()
}
