//! Batch-major sweep properties (DESIGN.md S22, no artifacts needed):
//! the interleaved `[pixel][n][c]` batch-major kernels must be
//! bit-identical to the image-major act-major driver, the per-MAC
//! LUT6_2 readout baseline (`NetworkPlan::compile_direct`) and the
//! fresh-allocation per-image path (`Executor::execute`) — on
//! randomized synthetic networks, across both datapaths, every batch
//! size in 1..=17 (ragged tails against the SIMD/tile widths included)
//! and several thread counts, through deliberately **poisoned** arenas.

mod common;

use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::Network;
use lutmul::graph::plan::NetworkPlan;
use lutmul::graph::ScratchPool;
use lutmul::util::prop::{self, Rng};

fn tensors_for(rng: &mut Rng, net: &Network, n: usize) -> Vec<Tensor> {
    let (s, c) = (net.meta.image_size, net.meta.in_ch);
    common::random_images(rng, net, n)
        .into_iter()
        .map(|d| Tensor::from_hwc(s, s, c, d))
        .collect()
}

#[test]
fn prop_batch_major_matches_image_major_and_fresh_allocation() {
    prop::cases(8, |rng| {
        let spec = common::random_spec(rng);
        let net = Network::synthetic(&spec, rng.next_u64());
        let nb = 1 + rng.below(17) as usize; // 1..=17
        let tensors = tensors_for(rng, &net, nb);
        for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
            let ex = Executor::new(&net, dp);
            // fresh-allocation per-image reference
            let want: Vec<Vec<f32>> = tensors.iter().map(|t| ex.execute(t)).collect();
            let mut pool = ScratchPool::new();
            let mut out = Vec::new();
            for threads in [1usize, 3, 8] {
                pool.dirty(rng.range_i32(-9, 9));
                ex.run_batch_into(&tensors, threads, &mut pool, &mut out);
                assert_eq!(out, want, "batch-major, nb={nb}, {threads} threads ({dp:?})");
                pool.dirty(rng.range_i32(-9, 9));
                ex.run_image_major_into(&tensors, threads, &mut pool, &mut out);
                assert_eq!(out, want, "image-major witness, nb={nb}, {threads} threads ({dp:?})");
            }
        }
    });
}

#[test]
fn prop_batch_major_matches_direct_and_mac_major_witnesses() {
    // the same batch-major sweep driven over the per-MAC LUT6_2 readout
    // and MAC-major table layouts (independent scalar witness bodies)
    prop::cases(6, |rng| {
        let spec = common::random_spec(rng);
        let net = Network::synthetic(&spec, rng.next_u64());
        let nb = 1 + rng.below(17) as usize;
        let tensors = tensors_for(rng, &net, nb);
        let act = Executor::new(&net, Datapath::LutFabric);
        let want: Vec<Vec<f32>> = tensors.iter().map(|t| act.execute(t)).collect();
        let direct = Executor::from_plan(NetworkPlan::compile_direct(&net, Datapath::LutFabric));
        let mac = Executor::from_plan(NetworkPlan::compile_mac_major(&net, Datapath::LutFabric));
        let mut pool = ScratchPool::new();
        let mut out = Vec::new();
        for (name, ex) in [("direct", &direct), ("mac-major", &mac), ("act-major", &act)] {
            for threads in [1usize, 4] {
                pool.dirty(-5);
                ex.run_batch_into(&tensors, threads, &mut pool, &mut out);
                assert_eq!(out, want, "{name} batch-major, nb={nb}, {threads} threads");
            }
        }
    });
}

#[test]
fn mobilenet_ragged_tails_stay_bit_exact_across_chunkings() {
    // pin the run_chunk tile-alignment policy: every batch size that
    // leaves a ragged tail against the plan's batch tile (a power of
    // two <= 16) and against LANES must still be bit-exact, at thread
    // counts that force uneven worker chunks
    let net = Network::synthetic(&mobilenet_v2_small(), 0xBA7C4);
    let ex = Executor::new(&net, Datapath::LutFabric);
    let tile = ex.plan().batch_tile();
    assert!(tile.is_power_of_two() && tile <= 16, "tile heuristic drifted: {tile}");
    let mut rng = Rng::new(0x7A115);
    let tensors = tensors_for(&mut rng, &net, 17);
    let want: Vec<Vec<f32>> = tensors.iter().map(|t| ex.execute(t)).collect();
    let mut pool = ScratchPool::new();
    let mut out = Vec::new();
    for nb in [1usize, 2, 5, 7, 8, 9, 13, 16, 17] {
        for threads in [1usize, 3, 8] {
            pool.dirty(-7);
            ex.run_batch_into(&tensors[..nb], threads, &mut pool, &mut out);
            assert_eq!(
                &out[..],
                &want[..nb],
                "ragged tail nb={nb}, tile={tile}, {threads} threads"
            );
        }
    }
}
