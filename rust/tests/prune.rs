//! Structured-pruning conformance suite (DESIGN.md S23, no artifacts
//! needed): a plan compiled with a `PruneSpec` must be bit-identical to
//! the *dense* compile of the same network with the mask zeroed into
//! its weights — on randomized synthetic networks, across every
//! datapath (arithmetic weights, per-MAC LUT6_2 readout, activation-
//! major tables, MAC-major tables), every batch size in 1..=17 and both
//! batch drivers. The dataflow simulator runs the same pruned plans
//! with fold-rescaled stages: its logits must match too, and its
//! measured steady-state throughput must agree with the analytic model.

mod common;

use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::network::{Network, Op};
use lutmul::graph::plan::NetworkPlan;
use lutmul::graph::{PruneSpec, ScratchPool};
use lutmul::util::prop::{self, Rng};

fn tensors_for(rng: &mut Rng, net: &Network, n: usize) -> Vec<Tensor> {
    let (s, c) = (net.meta.image_size, net.meta.in_ch);
    common::random_images(rng, net, n)
        .into_iter()
        .map(|d| Tensor::from_hwc(s, s, c, d))
        .collect()
}

/// A rotation of prune specs covering the spec surface: pure channel
/// sparsity at two densities, joint channel+tap sparsity, and an
/// explicit per-layer mask injected by name (the test-harness hook).
fn spec_for(rng: &mut Rng, net: &Network) -> PruneSpec {
    match rng.below(4) {
        0 => PruneSpec::channels(0.25),
        1 => PruneSpec::channels(0.5),
        2 => PruneSpec::channels_and_taps(0.5, 0.25),
        _ => {
            // explicit masks on the first 4-bit conv: keep alternate
            // output channels and drop the final weight column
            let mut spec = PruneSpec::channels(0.25);
            for op in &net.ops {
                if let Op::Conv { name, cout, w_bits, w_codes, .. } = op {
                    if *w_bits > 4 {
                        continue;
                    }
                    let chmask: Vec<bool> = (0..*cout).map(|i| i % 2 == 0).collect();
                    let cols = w_codes[0].len();
                    let mut colmask = vec![true; cols];
                    if cols > 1 {
                        colmask[cols - 1] = false;
                    }
                    spec = spec.with_channel_mask(name, chmask).with_tap_mask(name, colmask);
                    break;
                }
            }
            spec
        }
    }
}

/// The four (compile mode, datapath) combinations of the kernel engine,
/// built pruned; the masked-dense reference uses the same mode so each
/// sparse body is checked against its own dense witness.
fn pruned_and_masked(
    net: &Network,
    masked: &Network,
    spec: &PruneSpec,
    which: usize,
) -> (&'static str, Executor, Executor) {
    match which {
        0 => (
            "weights",
            Executor::from_plan(NetworkPlan::compile_pruned(net, Datapath::Arithmetic, spec)),
            Executor::from_plan(NetworkPlan::compile(masked, Datapath::Arithmetic)),
        ),
        1 => (
            "act-major",
            Executor::from_plan(NetworkPlan::compile_pruned(net, Datapath::LutFabric, spec)),
            Executor::from_plan(NetworkPlan::compile(masked, Datapath::LutFabric)),
        ),
        2 => (
            "direct",
            Executor::from_plan(NetworkPlan::compile_pruned_direct(net, Datapath::LutFabric, spec)),
            Executor::from_plan(NetworkPlan::compile_direct(masked, Datapath::LutFabric)),
        ),
        _ => (
            "mac-major",
            Executor::from_plan(NetworkPlan::compile_pruned_mac_major(
                net,
                Datapath::LutFabric,
                spec,
            )),
            Executor::from_plan(NetworkPlan::compile_mac_major(masked, Datapath::LutFabric)),
        ),
    }
}

#[test]
fn prop_pruned_plans_match_masked_dense_across_datapaths_and_batches() {
    prop::cases(8, |rng| {
        let spec_shape = common::random_spec(rng);
        let net = Network::synthetic(&spec_shape, rng.next_u64());
        let spec = spec_for(rng, &net);
        let masked = spec.masked_network(&net);
        let nb = 1 + rng.below(17) as usize; // 1..=17, ragged tails included
        let tensors = tensors_for(rng, &net, nb);
        let mut pool = ScratchPool::new();
        let (mut out, mut want) = (Vec::new(), Vec::new());
        for which in 0..4 {
            let (name, pruned, dense) = pruned_and_masked(&net, &masked, &spec, which);
            // masked-dense reference through the fresh-allocation path
            pool.dirty(rng.range_i32(-9, 9));
            dense.run_batch_into(&tensors, 1, &mut pool, &mut want);
            for threads in [1usize, 4] {
                pool.dirty(rng.range_i32(-9, 9));
                pruned.run_batch_into(&tensors, threads, &mut pool, &mut out);
                assert_eq!(out, want, "{name} batch-major, nb={nb}, {threads} threads");
                pool.dirty(rng.range_i32(-9, 9));
                pruned.run_image_major_into(&tensors, threads, &mut pool, &mut out);
                assert_eq!(out, want, "{name} image-major, nb={nb}, {threads} threads");
            }
        }
    });
}

#[test]
fn prop_pruned_plans_shrink_live_work_and_noop_is_identity() {
    prop::cases(8, |rng| {
        let spec_shape = common::random_spec(rng);
        let net = Network::synthetic(&spec_shape, rng.next_u64());
        let dense = NetworkPlan::compile(&net, Datapath::LutFabric);
        let pruned = NetworkPlan::compile_pruned(&net, Datapath::LutFabric, &PruneSpec::channels(0.5));
        // compacted tables drop the pruned rows' LUTs and MACs; the
        // strict checks fire whenever a layer of the matching kind
        // actually pruned (single-channel layers legitimately keep their
        // one surviving channel)
        assert!(pruned.lut_count() <= dense.lut_count());
        if pruned
            .convs()
            .any(|c| c.lut_count() > 0 && c.rows() < c.geom.cout)
        {
            assert!(pruned.lut_count() < dense.lut_count(), "no LUT savings at 50% sparsity");
        }
        let live: u64 = pruned.convs().map(|c| c.macs()).sum();
        let full: u64 = pruned.convs().map(|c| c.dense_macs()).sum();
        assert!(live <= full);
        if pruned.convs().any(|c| c.prune.is_some()) {
            assert!(live < full, "no MAC savings at 50% sparsity");
        }
        assert_eq!(full, dense.convs().map(|c| c.macs()).sum::<u64>());
        // a no-op spec compiles to a structurally dense plan
        let noop = NetworkPlan::compile_pruned(&net, Datapath::LutFabric, &PruneSpec::default());
        assert_eq!(noop.lut_count(), dense.lut_count());
        assert!(noop.convs().all(|c| c.prune.is_none()), "no-op spec left a prune record");
    });
}

#[test]
fn prop_pruned_pipeline_matches_masked_dense_and_analytic_fps() {
    prop::cases(6, |rng| {
        let spec_shape = common::random_spec(rng);
        let net = Network::synthetic(&spec_shape, rng.next_u64());
        let spec = PruneSpec::channels(0.5);
        let masked = spec.masked_network(&net);
        let pruned = NetworkPlan::compile_pruned(&net, Datapath::LutFabric, &spec);
        let dense = NetworkPlan::compile(&net, Datapath::LutFabric);

        let fold = 1 + rng.below(8) as usize;
        let base = FoldConfig::uniform(dense.n_convs(), fold);
        let rescaled = base.rescaled_for(&pruned);
        // generous FIFO depth: the throughput leg below compares the
        // measured interval against the analytic steady state, which
        // assumes stages are never backpressure-starved
        let dense_pipe = Pipeline::from_plan(&dense, &base, 64);
        let mut pipe = Pipeline::from_plan(&pruned, &rescaled, 64);
        assert!(
            pipe.steady_cycles() <= dense_pipe.steady_cycles(),
            "fold-rescaled pruned pipeline got slower: {} vs {}",
            pipe.steady_cycles(),
            dense_pipe.steady_cycles()
        );

        // enough images in flight for the incremental interval to reach
        // the steady-state regime
        let n = 8usize;
        let images = common::random_images(rng, &net, n);
        let report = pipe.run(&images).expect("pruned pipeline run");

        // logits: bit-exact vs the masked-dense executor
        let (s, c) = (net.meta.image_size, net.meta.in_ch);
        let tensors: Vec<Tensor> = images
            .iter()
            .map(|d| Tensor::from_hwc(s, s, c, d.clone()))
            .collect();
        let want = Executor::from_plan(NetworkPlan::compile(&masked, Datapath::LutFabric))
            .run_batch_with_threads(&tensors, 1);
        assert_eq!(report.logits, want, "pruned pipeline diverged from masked dense");

        // throughput: measured incremental interval within 15% of the
        // analytic steady-state interval
        let analytic = report.steady_state_cycles_per_image.max(1) as f64;
        let measured = report.incremental_cycles_per_image().max(1) as f64;
        let ratio = measured / analytic;
        assert!(
            (ratio - 1.0).abs() <= 0.15,
            "simulated interval {measured} vs analytic {analytic} (ratio {ratio:.3})"
        );
    });
}
