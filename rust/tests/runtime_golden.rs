//! PJRT runtime golden tests: load the AOT HLO artifacts, execute them on
//! the XLA CPU client from Rust, and check bit-exact agreement with both
//! the exported golden logits and every Rust execution backend.
//!
//! These tests require `make artifacts`; they skip gracefully otherwise.
//! The whole file is compiled only with the `xla` feature — without the
//! real PJRT bindings `Runtime::load` is a stub that always errors, so
//! these would fail spuriously (EXPERIMENTS.md "Test triage").
#![cfg(feature = "xla")]

use lutmul::coordinator::argmax;
use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::network::Network;
use lutmul::runtime::{Artifacts, Runtime};

fn setup() -> Option<(Network, Vec<Vec<i32>>, Vec<u8>, Artifacts)> {
    let a = Artifacts::new("artifacts");
    let net = Network::load(a.network_json()).ok()?;
    let (images, labels) =
        a.load_test_set(net.meta.image_size, net.meta.image_size, net.meta.in_ch).ok()?;
    if !a.model_hlo(1).exists() {
        return None;
    }
    Some((net, images, labels, a))
}

#[test]
fn pjrt_executes_batch1_artifact() {
    let Some((net, images, _, a)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(a.model_hlo(1), 1, 16, 16, 3, net.meta.num_classes).unwrap();
    let logits = rt.run(&images[0]).unwrap();
    assert_eq!(logits.len(), 1);
    assert_eq!(logits[0].len(), net.meta.num_classes);
    assert!(logits[0].iter().all(|v| v.is_finite()));
}

#[test]
fn pjrt_matches_exported_golden_logits() {
    let Some((net, images, _, a)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(a.model_hlo(1), 1, 16, 16, 3, net.meta.num_classes).unwrap();
    for (i, want) in net.meta.golden_logits.iter().enumerate().take(8) {
        let got = rt.run(&images[i]).unwrap();
        // <=2 ULP: old-XLA CPU emits an FMA for the final dense op, jax's
        // CPU jit (which produced the JSON golden) does not
        assert!(
            lutmul::util::slices_ulp_eq(&got[0], want, 2),
            "image {i}: PJRT vs JAX golden: {got:?} vs {want:?}"
        );
    }
}

#[test]
fn pjrt_matches_rust_executor_and_simulator() {
    // the full three-way agreement: AOT HLO (Pallas kernels inside) ==
    // reference executor == dataflow pipeline, bit for bit.
    let Some((net, images, _, a)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(a.model_hlo(1), 1, 16, 16, 3, net.meta.num_classes).unwrap();
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let mut pipe = Pipeline::build(&net, &FoldConfig::fully_parallel(net.convs().count()), 16);
    let n = 6;
    let sim = pipe.run(&images[..n]).unwrap();
    for i in 0..n {
        let golden = rt.run(&images[i]).unwrap();
        let t = Tensor::from_hwc(16, 16, 3, images[i].clone());
        assert_eq!(golden[0], ex.execute(&t), "image {i}: PJRT vs executor");
        assert_eq!(golden[0], sim.logits[i], "image {i}: PJRT vs simulator");
    }
}

#[test]
fn pjrt_batch8_artifact_consistent() {
    let Some((net, images, _, a)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    if !a.model_hlo(8).exists() {
        eprintln!("skipping: batch-8 artifact missing");
        return;
    }
    let rt8 = Runtime::load(a.model_hlo(8), 8, 16, 16, 3, net.meta.num_classes).unwrap();
    let rt1 = Runtime::load(a.model_hlo(1), 1, 16, 16, 3, net.meta.num_classes).unwrap();
    let batch = rt8.run_images(&images[..8].to_vec()).unwrap();
    for i in 0..8 {
        let single = rt1.run(&images[i]).unwrap();
        assert_eq!(batch[i], single[0], "batching must not change results");
    }
}

#[test]
fn pjrt_run_batched_chunks_pads_and_truncates() {
    // run_batched over a count that is not a multiple of the artifact's
    // batch geometry: chunking, zero-padding and truncation must be
    // invisible — per-image logits equal the batch-1 artifact's.
    let Some((net, images, _, a)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    if !a.model_hlo(8).exists() {
        eprintln!("skipping: batch-8 artifact missing");
        return;
    }
    let rt8 = Runtime::load(a.model_hlo(8), 8, 16, 16, 3, net.meta.num_classes).unwrap();
    let rt1 = Runtime::load(a.model_hlo(1), 1, 16, 16, 3, net.meta.num_classes).unwrap();
    let n = 11;
    let batched = rt8.run_batched(&images[..n]).unwrap();
    assert_eq!(batched.len(), n);
    for i in 0..n {
        assert_eq!(batched[i], rt1.run(&images[i]).unwrap()[0], "image {i}");
    }
}

#[test]
fn pjrt_accuracy_matches_export() {
    let Some((net, images, labels, a)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    if !a.model_hlo(8).exists() {
        return;
    }
    let rt8 = Runtime::load(a.model_hlo(8), 8, 16, 16, 3, net.meta.num_classes).unwrap();
    let n = 128;
    let mut correct = 0usize;
    for chunk in 0..(n / 8) {
        let imgs: Vec<Vec<i32>> = (0..8).map(|j| images[chunk * 8 + j].clone()).collect();
        let logits = rt8.run_images(&imgs).unwrap();
        for (j, l) in logits.iter().enumerate() {
            if argmax(l) == labels[chunk * 8 + j] as usize {
                correct += 1;
            }
        }
    }
    let acc = correct as f64 / n as f64;
    // deployed accuracy on this subset should track the export (exact
    // equality not required: subset vs full test set)
    assert!((acc - net.meta.acc_int).abs() < 0.08, "acc {acc} vs {}", net.meta.acc_int);
}

#[test]
fn runtime_rejects_bad_geometry() {
    let Some((net, _, _, a)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(a.model_hlo(1), 1, 16, 16, 3, net.meta.num_classes).unwrap();
    assert!(rt.run(&[0i32; 7]).is_err());
}
