//! Bench — batch-major serving throughput (EXPERIMENTS.md E9): images/s
//! vs batch size for the batch-major execution path on each serving
//! backend. No artifacts needed: runs on a synthetic network with the
//! trained `mobilenet_v2_small` shape.
//!
//! The acceptance line is printed at the end: `run_batch` at batch 8 must
//! deliver >= 2x the images/s of batch 1 on the `Reference` backend.
//!
//! Run: `cargo bench --bench bench_batch`

use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::Network;
use lutmul::util::bench::{bench, per_second};
use lutmul::util::prop::Rng;

fn main() {
    let net = Network::synthetic(&mobilenet_v2_small(), 0xBA7C4);
    let size = net.meta.image_size;
    let ch = net.meta.in_ch;
    let mut rng = Rng::new(1);
    let images: Vec<Tensor> = (0..32)
        .map(|_| Tensor::from_hwc(size, size, ch, rng.vec_i32(size * size * ch, 0, 15)))
        .collect();
    let flat: Vec<Vec<i32>> = images.iter().map(|t| t.data.clone()).collect();
    println!(
        "synthetic {} ({}x{}x{}), {} cores",
        "mobilenet_v2_small",
        size,
        size,
        ch,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );

    // --- Reference backend: images/s vs batch size ---------------------
    println!("\nReference backend (persistent executor, run_batch):");
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let mut ips_at = std::collections::BTreeMap::new();
    for b in [1usize, 2, 4, 8, 16, 32] {
        let batch = &images[..b];
        let iters = (128 / b).clamp(8, 64);
        let r = bench(&format!("run_batch: batch={b:<2}"), iters, || ex.run_batch(batch).len());
        let ips = per_second(b, &r);
        ips_at.insert(b, ips);
        println!("    -> {ips:.0} img/s ({:.2}x vs batch=1)", ips / ips_at[&1]);
    }

    // --- LutFabric backend (hardware-true datapath) ---------------------
    println!("\nLutFabric backend (every 4-bit mult via LUT6_2 readout):");
    let exf = Executor::new(&net, Datapath::LutFabric);
    let mut lut_ips = std::collections::BTreeMap::new();
    for b in [1usize, 8] {
        let batch = &images[..b];
        let r = bench(&format!("run_batch: batch={b:<2}"), 4, || exf.run_batch(batch).len());
        lut_ips.insert(b, per_second(b, &r));
        println!("    -> {:.0} img/s", lut_ips[&b]);
    }

    // --- Simulator backend: batch pipelining in simulated cycles --------
    println!("\nSimulator backend (cycle-level, batch-pipelined):");
    let folds = FoldConfig::fully_parallel(net.convs().count());
    let cold = Pipeline::build(&net, &folds, 16).run(&flat[..1]);
    let warm = Pipeline::build(&net, &folds, 16).run(&flat[..8]);
    println!(
        "    cold single image: {} cycles | batch of 8: {} cycles total, marginal image {} cycles",
        cold.cycles,
        warm.cycles,
        warm.incremental_cycles_per_image()
    );
    println!(
        "    -> batch pipelining: {:.2}x cycles/image vs draining between images",
        8.0 * cold.cycles as f64 / warm.cycles as f64
    );

    // --- acceptance line -------------------------------------------------
    let speedup = ips_at[&8] / ips_at[&1];
    println!(
        "\nbatch=8 vs batch=1 on Reference: {:.2}x images/s (target >= 2x): {}",
        speedup,
        if speedup >= 2.0 { "PASS" } else { "FAIL" }
    );
    let lut_speedup = lut_ips[&8] / lut_ips[&1];
    println!("batch=8 vs batch=1 on LutFabric: {lut_speedup:.2}x images/s");
}
