//! Bench — batch-major serving throughput + plan compilation
//! (EXPERIMENTS.md E9/E10): images/s vs batch size for the batch-major
//! execution path on each serving backend, and the per-image speedup of
//! compiled layer plans (DESIGN.md S17) over direct multiplier readout
//! on both datapaths (the Arithmetic pair shares its multipliers either
//! way and serves as the ~1x noise control; the LutFabric pair isolates
//! the product-table memoization win over per-MAC LUT6_2 readout). No
//! artifacts needed: runs on a synthetic network with the trained
//! `mobilenet_v2_small` shape.
//!
//! Acceptance lines printed at the end (the process exits nonzero on
//! FAIL, so CI can gate on the bench):
//!  * `run_batch` at batch 8 must deliver >= 2x the images/s of batch 1
//!    on the `Reference` backend (informational under `--smoke`, where
//!    runner core counts vary);
//!  * compiled plans must deliver >= 3x the per-image throughput of the
//!    per-MAC LUT6_2 readout on the `LutFabric` datapath.
//!
//! Run: `cargo bench --bench bench_batch` (`-- --smoke` for a one-shot
//! CI-sized run, also reachable as `make bench-smoke`).

use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::Network;
use lutmul::graph::plan::NetworkPlan;
use lutmul::util::bench::{bench, per_second, BenchResult};
use lutmul::util::prop::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let net = Network::synthetic(&mobilenet_v2_small(), 0xBA7C4);
    let io = net.io();
    let (size, ch) = (io.image_size, io.in_ch);
    let mut rng = Rng::new(1);
    let images: Vec<Tensor> = (0..32)
        .map(|_| Tensor::from_hwc(size, size, ch, rng.vec_i32(size * size * ch, 0, 15)))
        .collect();
    let flat: Vec<Vec<i32>> = images.iter().map(|t| t.data.clone()).collect();
    println!(
        "synthetic {} ({}x{}x{}), {} cores{}",
        "mobilenet_v2_small",
        size,
        size,
        ch,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        if smoke { " [smoke: 1 iter]" } else { "" }
    );

    // --- Reference backend: images/s vs batch size ---------------------
    println!("\nReference backend (persistent executor, run_batch):");
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let mut ips_at = std::collections::BTreeMap::new();
    let batch_sizes: &[usize] = if smoke { &[1, 8] } else { &[1, 2, 4, 8, 16, 32] };
    for &b in batch_sizes {
        let batch = &images[..b];
        let iters = if smoke { 1 } else { (128 / b).clamp(8, 64) };
        let r = bench(&format!("run_batch: batch={b:<2}"), iters, || ex.run_batch(batch).len());
        let ips = per_second(b, &r);
        ips_at.insert(b, ips);
        println!("    -> {ips:.0} img/s ({:.2}x vs batch=1)", ips / ips_at[&1]);
    }

    // --- plan compilation: per-image speedup on both datapaths ----------
    // "before" = NetworkPlan::compile_direct: on LutFabric, per-MAC
    // LUT6_2 readout (the pre-memoization datapath); on Arithmetic the
    // direct and compiled plans share the same multipliers, so that row
    // is a CONTROL — it should read ~1.0x, and isolates the memoization
    // win on the LutFabric row from run-to-run noise.
    println!("\nPlan compilation (direct multiplier readout -> compiled plans), single image:");
    let iters = if smoke { 1 } else { 16 };
    let single = &images[..1];
    fn per_image(label: &str, iters: usize, single: &[Tensor], e: &Executor) -> BenchResult {
        bench(label, iters, || e.run_batch(single).len())
    }
    let arith_direct = Executor::from_plan(NetworkPlan::compile_direct(&net, Datapath::Arithmetic));
    let ra0 = per_image("Arithmetic control (direct plan)   ", iters, single, &arith_direct);
    let ra1 = per_image("Arithmetic control (compiled plan) ", iters, single, &ex);
    let lut_direct = Executor::from_plan(NetworkPlan::compile_direct(&net, Datapath::LutFabric));
    let lut = Executor::new(&net, Datapath::LutFabric);
    let rl0 = per_image("LutFabric  before (per-MAC readout)", iters, single, &lut_direct);
    let rl1 = per_image("LutFabric  after  (product tables) ", iters, single, &lut);
    let arith_speedup = ra0.median.as_secs_f64() / ra1.median.as_secs_f64();
    let lut_speedup = rl0.median.as_secs_f64() / rl1.median.as_secs_f64();
    println!(
        "    Arithmetic: {:.0} -> {:.0} img/s ({arith_speedup:.2}x, control: same multipliers, expect ~1x)",
        per_second(1, &ra0),
        per_second(1, &ra1)
    );
    println!(
        "    LutFabric:  {:.0} -> {:.0} img/s ({lut_speedup:.2}x)",
        per_second(1, &rl0),
        per_second(1, &rl1)
    );

    // --- LutFabric backend batch scaling --------------------------------
    println!("\nLutFabric backend (compiled product tables, run_batch):");
    let mut lut_ips = std::collections::BTreeMap::new();
    for b in [1usize, 8] {
        let batch = &images[..b];
        let r = bench(
            &format!("run_batch: batch={b:<2}"),
            if smoke { 1 } else { 4 },
            || lut.run_batch(batch).len(),
        );
        lut_ips.insert(b, per_second(b, &r));
        println!("    -> {:.0} img/s", lut_ips[&b]);
    }

    // --- Simulator backend: batch pipelining in simulated cycles --------
    println!("\nSimulator backend (cycle-level, batch-pipelined):");
    let plan = ex.plan();
    let folds = FoldConfig::fully_parallel(plan.n_convs());
    let cold = Pipeline::from_plan(plan, &folds, 16).run(&flat[..1]).unwrap();
    let warm = Pipeline::from_plan(plan, &folds, 16).run(&flat[..8]).unwrap();
    println!(
        "    cold single image: {} cycles | batch of 8: {} cycles total, marginal image {} cycles",
        cold.cycles,
        warm.cycles,
        warm.incremental_cycles_per_image()
    );
    println!(
        "    -> batch pipelining: {:.2}x cycles/image vs draining between images",
        8.0 * cold.cycles as f64 / warm.cycles as f64
    );

    // --- acceptance lines (the process exits nonzero on FAIL so the CI
    // smoke step actually gates; the core-count-dependent batch-scaling
    // target is informational under --smoke, where CI runner core counts
    // vary) --------------------------------------------------------------
    let speedup = ips_at[&8] / ips_at[&1];
    let batch_ok = speedup >= 2.0;
    println!(
        "\nbatch=8 vs batch=1 on Reference: {speedup:.2}x images/s (target >= 2x): {}",
        if batch_ok { "PASS" } else if smoke { "FAIL (informational under --smoke)" } else { "FAIL" }
    );
    let plan_ok = lut_speedup >= 3.0;
    println!(
        "plan compilation on LutFabric: {lut_speedup:.2}x per-image (target >= 3x): {}",
        if plan_ok { "PASS" } else { "FAIL" }
    );
    let lut_batch = lut_ips[&8] / lut_ips[&1];
    println!("batch=8 vs batch=1 on LutFabric: {lut_batch:.2}x images/s");
    if !plan_ok || (!batch_ok && !smoke) {
        std::process::exit(1);
    }
}
