//! Bench — host-side performance of the L3 hot paths: the dataflow
//! pipeline simulator, the reference executor (serving fast path), the
//! LUT-fabric datapath, and the serving coordinator. This is the §Perf
//! harness of EXPERIMENTS.md: the simulator must regenerate Table 2-class
//! experiments in seconds and the coordinator must not be the bottleneck.
//!
//! Needs `make artifacts`. Run: `cargo bench --bench bench_dataflow`

use std::sync::Arc;

use lutmul::coordinator::{Backend, Coordinator, ServeConfig};
use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::network::Network;
use lutmul::runtime::{Artifacts, Runtime};
use lutmul::util::bench::{bench, per_second};

fn main() {
    let a = Artifacts::new("artifacts");
    let Ok(net) = Network::load(a.network_json()) else {
        eprintln!("artifacts missing — run `make artifacts` first");
        return;
    };
    let (images, _) =
        a.load_test_set(net.meta.image_size, net.meta.image_size, net.meta.in_ch).unwrap();
    let n = 64usize;
    let macs_per_img: u64 = lutmul::graph::mobilenet_v2_small().ops_per_image() / 2;

    // --- reference executor (serving fast path) ---
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let tensors: Vec<Tensor> =
        images[..n].iter().map(|i| Tensor::from_hwc(16, 16, 3, i.clone())).collect();
    let r = bench("executor: 64 images (arithmetic)", 20, || {
        tensors.iter().map(|t| ex.execute(t)[0]).sum::<f32>()
    });
    println!(
        "    -> {:.0} img/s | {:.1} M MAC/s host",
        per_second(n, &r),
        per_second(n, &r) * macs_per_img as f64 / 1e6
    );

    // --- LUT-fabric datapath (hardware-true, every mult via LUT readout) ---
    let exf = Executor::new(&net, Datapath::LutFabric);
    let r = bench("executor: 8 images (LUT6 fabric datapath)", 5, || {
        tensors[..8].iter().map(|t| exf.execute(t)[0]).sum::<f32>()
    });
    println!("    -> {:.0} img/s", per_second(8, &r));

    // --- dataflow pipeline simulator ---
    for fold in [1usize, 4] {
        let folds = if fold == 1 {
            FoldConfig::fully_parallel(net.convs().count())
        } else {
            FoldConfig::uniform(net.convs().count(), fold)
        };
        let mut pipe = Pipeline::build(&net, &folds, 16);
        let imgs = images[..n].to_vec();
        let r = bench(&format!("pipeline sim: 64 images (fold={fold})"), 10, || {
            pipe.run(&imgs).unwrap().cycles
        });
        println!(
            "    -> {:.0} img/s | {:.2} M simulated MAC-lookups/s",
            per_second(n, &r),
            per_second(n, &r) * macs_per_img as f64 / 1e6
        );
    }

    // --- sharded chain (DESIGN.md S18): 2 and 3 simulated devices over
    // 100 GbE; host throughput of the whole-chain co-simulation ---
    for devices in [2usize, 3] {
        use lutmul::dataflow::multi::LinkModel;
        use lutmul::dataflow::ShardChain;
        use lutmul::graph::plan::NetworkPlan;
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let shards = plan.shard_evenly(devices);
        let folds = FoldConfig::fully_parallel(plan.n_convs());
        let mut chain = ShardChain::new(
            &shards,
            &folds,
            16,
            &LinkModel::gbe100(),
            333.0,
            net.meta.a_bits.max(1),
        )
        .expect("balanced shards chain");
        let imgs = images[..n].to_vec();
        let r = bench(&format!("shard chain sim: 64 images ({devices} devices)"), 10, || {
            chain.run(&imgs).unwrap().cycles
        });
        println!("    -> {:.0} img/s host", per_second(n, &r));
    }

    // --- PJRT golden runtime ---
    if let Ok(rt) = Runtime::load(a.model_hlo(8), 8, 16, 16, 3, net.meta.num_classes) {
        let batch: Vec<Vec<i32>> = images[..8].to_vec();
        let r = bench("PJRT runtime: batch of 8 (AOT HLO w/ Pallas)", 20, || {
            rt.run_images(&batch).unwrap().len()
        });
        println!("    -> {:.0} img/s", per_second(8, &r));
    }

    // --- serving coordinator end to end ---
    let coord = Coordinator::start(
        Arc::new(net),
        ServeConfig { backend: Backend::Reference, workers: 2, max_batch: 16, ..Default::default() },
    );
    let r = bench("coordinator: 256 requests end-to-end", 5, || {
        let tickets: Vec<_> = (0..256)
            .map(|i| coord.submit(images[i % images.len()].clone()).unwrap())
            .collect();
        tickets.into_iter().map(|t| t.wait().unwrap().class).sum::<usize>()
    });
    println!("    -> {:.0} req/s | {}", per_second(256, &r), coord.metrics());
    coord.shutdown();
}
