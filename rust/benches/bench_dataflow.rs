//! Bench — host-side performance of the L3 hot paths: the dataflow
//! pipeline simulator, the reference executor (serving fast path), the
//! LUT-fabric datapath, the sharded chain and the serving coordinator.
//! All surfaces are driven through the engine's uniform
//! `InferenceBackend` contract (DESIGN.md S19). This is the §Perf
//! harness of EXPERIMENTS.md: the simulator must regenerate Table
//! 2-class experiments in seconds and the coordinator must not be the
//! bottleneck.
//!
//! Needs `make artifacts`. Run: `cargo bench --bench bench_dataflow`

use lutmul::coordinator::{Coordinator, ServeConfig};
use lutmul::engine::{Arch, BackendKind, Engine, Folding};
use lutmul::graph::plan::Datapath;
use lutmul::runtime::Artifacts;
use lutmul::util::bench::{bench, per_second};

fn main() {
    let a = Artifacts::new("artifacts");
    // no synthetic fallback: this bench tracks the trained artifacts
    let mut engine = match Engine::builder()
        .arch(Arch::Small)
        .artifacts(&a)
        .backend(BackendKind::Reference)
        .build()
    {
        Ok(e) => e,
        Err(_) => {
            eprintln!("artifacts missing — run `make artifacts` first");
            return;
        }
    };
    let (images, _) = engine.labeled_test_set().unwrap();
    let n = 64usize;
    let imgs = images[..n].to_vec();
    let macs_per_img: u64 = lutmul::graph::mobilenet_v2_small().ops_per_image() / 2;

    // --- reference executor (serving fast path) ---
    // NB: batch-major across all cores (the serving path), NOT the
    // pre-S19 single-threaded per-image `execute` row — img/s here is
    // not comparable with §Perf entries recorded before PR 4
    let r = bench("executor: 64-image batch (arithmetic, all cores)", 20, || {
        engine.infer_batch(&imgs).unwrap().logits.len()
    });
    println!(
        "    -> {:.0} img/s | {:.1} M MAC/s host",
        per_second(n, &r),
        per_second(n, &r) * macs_per_img as f64 / 1e6
    );

    // --- LUT-fabric datapath (hardware-true, memoized product tables) ---
    let mut lut_engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(&a)
        .datapath(Datapath::LutFabric)
        .backend(BackendKind::Reference)
        .build()
        .unwrap();
    let r = bench("executor: 8-image batch (LUT6 fabric, all cores)", 5, || {
        lut_engine.infer_batch(&imgs[..8]).unwrap().logits.len()
    });
    println!("    -> {:.0} img/s", per_second(8, &r));

    // --- dataflow pipeline simulator ---
    for fold in [1usize, 4] {
        let folding = if fold == 1 { Folding::FullyParallel } else { Folding::Uniform(fold) };
        let mut pipe_engine = Engine::builder()
            .arch(Arch::Small)
            .artifacts(&a)
            .folding(folding)
            .backend(BackendKind::Pipeline)
            .build()
            .unwrap();
        let r = bench(&format!("pipeline sim: 64 images (fold={fold})"), 10, || {
            pipe_engine.infer_batch(&imgs).unwrap().cycles
        });
        println!(
            "    -> {:.0} img/s | {:.2} M simulated MAC-lookups/s",
            per_second(n, &r),
            per_second(n, &r) * macs_per_img as f64 / 1e6
        );
    }

    // --- sharded chain (DESIGN.md S18): 2 and 3 simulated devices over
    // 100 GbE; host throughput of the whole-chain co-simulation ---
    for devices in [2usize, 3] {
        let mut chain = engine
            .make_backend(BackendKind::Sharded { devices })
            .expect("balanced shards chain");
        let r = bench(&format!("shard chain sim: 64 images ({devices} devices)"), 10, || {
            chain.infer_batch(&imgs).unwrap().cycles
        });
        println!("    -> {:.0} img/s host", per_second(n, &r));
    }

    // --- PJRT golden runtime ---
    if let Ok(mut rt) = engine.make_backend(BackendKind::Pjrt { batch: 8 }) {
        let batch: Vec<Vec<i32>> = images[..8].to_vec();
        let r = bench("PJRT runtime: batch of 8 (AOT HLO w/ Pallas)", 20, || {
            rt.infer_batch(&batch).unwrap().logits.len()
        });
        println!("    -> {:.0} img/s", per_second(8, &r));
    }

    // --- serving coordinator end to end ---
    let coord = Coordinator::start(
        &engine,
        ServeConfig { workers: 2, max_batch: 16, ..Default::default() },
    )
    .unwrap();
    let r = bench("coordinator: 256 requests end-to-end", 5, || {
        let tickets: Vec<_> = (0..256)
            .map(|i| coord.submit(images[i % images.len()].clone()).unwrap())
            .collect();
        tickets.into_iter().map(|t| t.wait().unwrap().class).sum::<usize>()
    });
    println!("    -> {:.0} req/s | {}", per_second(256, &r), coord.metrics());
    coord.shutdown();
}
