//! Bench E7 — the headline claim: LUT-mapped MACs exceed the DSP-bound
//! peak at equal resources (Eq. 1 with LUT-derived PE counts vs DSP
//! packing), across bit-widths and devices.
//!
//! Run: `cargo bench --bench bench_peak`

use lutmul::fabric::device::{all_fpgas, U280};
use lutmul::roofline::{dsp_peak, lutmul_luts_per_mac, lutmul_peak};
use lutmul::util::bench::bench;

fn main() {
    println!("== E7: peak performance, LUTMUL vs DSP packing ==\n");
    println!("whole-device peaks at each device's max dataflow frequency:");
    println!(
        "{:<14}{:>8}{:>14}{:>14}{:>8}",
        "device", "bits", "DSP GOPS", "LUTMUL GOPS", "ratio"
    );
    for dev in all_fpgas() {
        for bits in [4u32, 8] {
            let s = dev.fraction(1);
            let f = dev.max_freq_mhz * 1e6;
            let d = dsp_peak(&s, bits, f) / 1e9;
            let l = lutmul_peak(&s, bits, f) / 1e9;
            println!("{:<14}{:>8}{:>14.0}{:>14.0}{:>8.2}", dev.name, bits, d, l, l / d);
        }
    }

    println!("\nall-in LUT cost per LUTMUL MAC (ROM + amortized adder):");
    for bits in [1u32, 2, 3, 4, 5, 6, 8] {
        println!("  {bits}-bit: {:.2} LUT6", lutmul_luts_per_mac(bits));
    }

    println!("\ncrossover: smallest bit-width where DSP packing wins on U280:");
    let s = U280.fraction(1);
    let f = U280.max_freq_mhz * 1e6;
    let mut crossover = None;
    for bits in 1..=16u32 {
        if dsp_peak(&s, bits, f) > lutmul_peak(&s, bits, f) {
            crossover = Some(bits);
            break;
        }
    }
    match crossover {
        Some(b) => println!("  DSP wins from {b}-bit up (LUT ROMs grow 2^n)"),
        None => println!("  LUTMUL wins at every bit-width <= 16"),
    }

    println!();
    bench("peak sweep: 5 devices x 2 bit-widths", 10_000, || {
        let mut acc = 0.0;
        for dev in all_fpgas() {
            for bits in [4u32, 8] {
                acc += lutmul_peak(&dev.fraction(1), bits, dev.max_freq_mhz * 1e6);
            }
        }
        acc
    });
}
