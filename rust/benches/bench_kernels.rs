//! Bench — batch-major SIMD LUT-GEMM vs the image-major sweep, plus
//! the table-layout ladder (DESIGN.md S20/S22, EXPERIMENTS.md E13/E15):
//! single-thread throughput of the compiled kernels at batch 8 through
//! a persistent `ScratchPool` (the steady-state serving configuration —
//! zero per-image allocation). No artifacts needed: runs on a synthetic
//! network with the trained `mobilenet_v2_small` shape.
//!
//! Acceptance lines printed at the end (the process exits nonzero on
//! FAIL, so CI can gate on the bench — `make kernel-smoke`):
//!  * every layout/datapath/batch-driver must be bit-identical on every
//!    image;
//!  * activation-major tables >= 1.5x the MAC-major per-image
//!    throughput single-threaded (>= 1.2x under `--smoke`);
//!  * the batch-major sweep >= 1.5x the image-major act-major driver at
//!    batch 8 single-threaded (same bar under `--smoke`: the
//!    warmup + median-of-k timing makes the ratio stable on shared
//!    runners, so the smoke gate is not discounted);
//!  * the structurally pruned compile at 50% channel sparsity
//!    (DESIGN.md S23) bit-exact against the dense compile of the masked
//!    network AND >= 1.3x its single-thread batch-major throughput —
//!    dropped channels must convert into real cycles, not just smaller
//!    tables (`make prune-smoke`).
//!
//! Run: `cargo bench --bench bench_kernels` (`-- --smoke` for the
//! CI-sized run, also reachable as `make kernel-smoke`).

use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::Network;
use lutmul::graph::plan::NetworkPlan;
use lutmul::graph::{PruneSpec, ScratchPool};
use lutmul::util::bench::{bench_warm, per_second};
use lutmul::util::prop::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let net = Network::synthetic(&mobilenet_v2_small(), 0x5EED_CAFE);
    let io = net.io();
    let (size, ch) = (io.image_size, io.in_ch);
    let mut rng = Rng::new(2);
    let batch = 8usize;
    let images: Vec<Tensor> = (0..batch)
        .map(|_| Tensor::from_hwc(size, size, ch, rng.vec_i32(size * size * ch, 0, 15)))
        .collect();
    println!(
        "synthetic mobilenet_v2_small ({size}x{size}x{ch}), single thread, batch {batch}{}",
        if smoke { " [smoke]" } else { "" }
    );

    // every layout and datapath over the same network
    let arith = Executor::new(&net, Datapath::Arithmetic);
    let act = Executor::new(&net, Datapath::LutFabric);
    let mac = Executor::from_plan(NetworkPlan::compile_mac_major(&net, Datapath::LutFabric));
    let direct = Executor::from_plan(NetworkPlan::compile_direct(&net, Datapath::LutFabric));

    // --- bit-exactness across layouts, datapaths and batch drivers ------
    // reference: per-image execute on the arithmetic datapath
    let want: Vec<Vec<f32>> = images.iter().map(|t| arith.execute(t)).collect();
    let mut diverged = 0usize;
    let mut check = |name: &str, got: Vec<Vec<f32>>| {
        if got != want {
            println!("DIVERGED: {name} disagrees with per-image Arithmetic");
            diverged += 1;
        }
    };
    let image_major = |ex: &Executor| {
        let mut pool = ScratchPool::new();
        let mut out = Vec::new();
        ex.run_image_major_into(&images, 1, &mut pool, &mut out);
        out
    };
    check("batch-major arithmetic", arith.run_batch_with_threads(&images, 1));
    check("batch-major act-major", act.run_batch_with_threads(&images, 1));
    check("batch-major mac-major", mac.run_batch_with_threads(&images, 1));
    check("batch-major direct", direct.run_batch_with_threads(&images, 1));
    check("image-major act-major", image_major(&act));
    check("image-major direct", image_major(&direct));
    let checks = 6usize;
    println!("bit-exactness: {}/{checks} kernel paths match the reference", checks - diverged);

    // --- single-thread throughput per kernel path -----------------------
    // persistent arenas; warmup + median-of-k so one preempted run
    // can't flip a gate on a shared CI runner
    let (warmup, iters) = if smoke { (3, 7) } else { (3, 15) };
    let time = |name: &str, ex: &Executor, batch_major: bool| {
        let mut pool = ScratchPool::new();
        let mut out = Vec::new();
        let r = bench_warm(name, warmup, iters, || {
            if batch_major {
                ex.run_batch_into(&images, 1, &mut pool, &mut out);
            } else {
                ex.run_image_major_into(&images, 1, &mut pool, &mut out);
            }
            out.len()
        });
        per_second(batch, &r)
    };
    println!("\nsingle-thread images/s (persistent arena, batch {batch}):");
    let ips_batch = time("LutFabric   act-major BATCH-major (S22)", &act, true);
    let ips_act = time("LutFabric   act-major image-major      ", &act, false);
    let ips_mac = time("LutFabric   mac-major image-major     ", &mac, false);
    let ips_direct = time("LutFabric   per-MAC LUT6_2 readout     ", &direct, false);
    let ips_arith = time("Arithmetic  batch-major                ", &arith, true);
    println!(
        "    batch-major {ips_batch:.0} | act-major {ips_act:.0} | mac-major {ips_mac:.0} \
         | direct {ips_direct:.0} | arith {ips_arith:.0} img/s"
    );

    // --- structured pruning (DESIGN.md S23, `make prune-smoke`) ---------
    // 50% magnitude channel sparsity: the compacted plan must reproduce
    // the dense compile of the masked network bit-for-bit (its own
    // reference — pruning changes the logits vs the unpruned net by
    // design) and convert the dropped rows into real throughput
    let spec = PruneSpec::channels(0.5);
    let masked = Executor::from_plan(NetworkPlan::compile(
        &spec.masked_network(&net),
        Datapath::LutFabric,
    ));
    let sparse =
        Executor::from_plan(NetworkPlan::compile_pruned(&net, Datapath::LutFabric, &spec));
    let prune_exact = sparse.run_batch_with_threads(&images, 1)
        == masked.run_batch_with_threads(&images, 1);
    println!("\nstructured pruning, 50% channel sparsity:");
    if !prune_exact {
        println!("DIVERGED: pruned plan disagrees with the masked-dense compile");
    }
    let ips_masked = time("LutFabric   masked-dense witness       ", &masked, true);
    let ips_sparse = time("LutFabric   sparse compacted (S23)     ", &sparse, true);

    // --- acceptance lines ----------------------------------------------
    let layout_speedup = ips_act / ips_mac;
    let layout_target = if smoke { 1.2 } else { 1.5 };
    let layout_ok = layout_speedup >= layout_target;
    println!(
        "\nactivation-major vs MAC-major tables: {layout_speedup:.2}x img/s single-thread \
         (target >= {layout_target}x): {}",
        if layout_ok { "PASS" } else { "FAIL" }
    );
    let batch_speedup = ips_batch / ips_act;
    let batch_target = 1.5;
    let batch_ok = batch_speedup >= batch_target;
    println!(
        "batch-major vs image-major act-major at batch {batch}: {batch_speedup:.2}x img/s \
         single-thread (target >= {batch_target}x): {}",
        if batch_ok { "PASS" } else { "FAIL" }
    );
    let memo = ips_act / ips_direct;
    println!("activation-major vs per-MAC readout: {memo:.2}x (informational)");
    let prune_speedup = ips_sparse / ips_masked;
    let prune_target = 1.3;
    let prune_ok = prune_exact && prune_speedup >= prune_target;
    println!(
        "sparse compacted vs masked-dense at 50% sparsity: {prune_speedup:.2}x img/s \
         single-thread (target >= {prune_target}x, bit-exact {}): {}",
        if prune_exact { "yes" } else { "NO" },
        if prune_ok { "PASS" } else { "FAIL" }
    );
    if diverged > 0 || !layout_ok || !batch_ok || !prune_ok {
        std::process::exit(1);
    }
}
