//! Bench — activation-major LUT-GEMM kernels vs the MAC-major layout
//! (DESIGN.md S20, EXPERIMENTS.md E13): single-thread per-image
//! throughput of the compiled `LutTables` kernels in both table
//! layouts, plus the per-MAC LUT6_2 readout and the arithmetic datapath
//! for context. No artifacts needed: runs on a synthetic network with
//! the trained `mobilenet_v2_small` shape, through a persistent
//! `ScratchPool` (the steady-state serving configuration — zero
//! per-image allocation).
//!
//! Acceptance lines printed at the end (the process exits nonzero on
//! FAIL, so CI can gate on the bench):
//!  * every layout/datapath must be bit-identical on every image;
//!  * the activation-major kernels must deliver >= 1.5x the MAC-major
//!    per-image throughput single-threaded (>= 1.2x under `--smoke`,
//!    where one-iteration timings on shared CI runners are noisy).
//!
//! Run: `cargo bench --bench bench_kernels` (`-- --smoke` for the
//! CI-sized run, also reachable as `make kernel-smoke`).

use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::mobilenet_v2_small;
use lutmul::graph::network::Network;
use lutmul::graph::plan::NetworkPlan;
use lutmul::graph::ScratchPool;
use lutmul::util::bench::{bench, per_second};
use lutmul::util::prop::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let net = Network::synthetic(&mobilenet_v2_small(), 0x5EED_CAFE);
    let io = net.io();
    let (size, ch) = (io.image_size, io.in_ch);
    let mut rng = Rng::new(2);
    let batch = 8usize;
    let images: Vec<Tensor> = (0..batch)
        .map(|_| Tensor::from_hwc(size, size, ch, rng.vec_i32(size * size * ch, 0, 15)))
        .collect();
    println!(
        "synthetic mobilenet_v2_small ({size}x{size}x{ch}), single thread, batch {batch}{}",
        if smoke { " [smoke]" } else { "" }
    );

    // every layout and datapath over the same network
    let arith = Executor::new(&net, Datapath::Arithmetic);
    let act = Executor::new(&net, Datapath::LutFabric);
    let mac = Executor::from_plan(NetworkPlan::compile_mac_major(&net, Datapath::LutFabric));
    let direct = Executor::from_plan(NetworkPlan::compile_direct(&net, Datapath::LutFabric));

    // --- bit-exactness across layouts and datapaths ---------------------
    let want = arith.run_batch_with_threads(&images, 1);
    let mut diverged = 0usize;
    for (name, ex) in [("act-major", &act), ("mac-major", &mac), ("direct", &direct)] {
        if ex.run_batch_with_threads(&images, 1) != want {
            println!("DIVERGED: LutFabric {name} disagrees with Arithmetic");
            diverged += 1;
        }
    }
    println!("bit-exactness: {}/3 LUT layouts match the arithmetic datapath", 3 - diverged);

    // --- single-thread throughput per layout ----------------------------
    // persistent arenas: the steady-state serving configuration
    let iters = if smoke { 2 } else { 12 };
    let mut time = |name: &str, ex: &Executor| {
        let mut pool = ScratchPool::new();
        let mut out = Vec::new();
        ex.run_batch_into(&images, 1, &mut pool, &mut out); // warm the arena
        let r = bench(name, iters, || {
            ex.run_batch_into(&images, 1, &mut pool, &mut out);
            out.len()
        });
        per_second(batch, &r)
    };
    println!("\nsingle-thread images/s (persistent arena, batch {batch}):");
    let ips_arith = time("Arithmetic  (compiled plan)          ", &arith);
    let ips_act = time("LutFabric   act-major tables (LUT-GEMM)", &act);
    let ips_mac = time("LutFabric   mac-major tables (pre-PR)  ", &mac);
    let ips_direct = time("LutFabric   per-MAC LUT6_2 readout     ", &direct);
    println!("    Arithmetic {ips_arith:.0} | act-major {ips_act:.0} | mac-major {ips_mac:.0} | direct {ips_direct:.0} img/s");

    // --- acceptance lines ----------------------------------------------
    let speedup = ips_act / ips_mac;
    let target = if smoke { 1.2 } else { 1.5 };
    let layout_ok = speedup >= target;
    println!(
        "\nactivation-major vs MAC-major tables: {speedup:.2}x img/s single-thread \
         (target >= {target}x): {}",
        if layout_ok { "PASS" } else { "FAIL" }
    );
    let memo = ips_act / ips_direct;
    println!("activation-major vs per-MAC readout: {memo:.2}x (informational)");
    if diverged > 0 || !layout_ok {
        std::process::exit(1);
    }
}
