//! Bench E1/E2 — Table 1 + Figure 1 regeneration: roofline curves for
//! 1/64 of the U280 (LUTMUL vs DSP architectures at several bit-widths).
//!
//! Run: `cargo bench --bench bench_roofline`

use lutmul::fabric::device::U280;
use lutmul::roofline;
use lutmul::util::bench::bench;

fn main() {
    println!("== E1: Table 1 ==\n");
    lutmul::reports::table1();
    println!("\n== E2: Figure 1 ==\n");
    lutmul::reports::fig1();
    println!();
    bench("fig1: full curve set (4 architectures x 29 points)", 200, || {
        roofline::figure1_curves(&U280, 64).len()
    });

    // ablation: the LUTMUL/DSP peak ratio across device fractions
    println!("\nLUTMUL/DSP 4-bit peak ratio vs device fraction:");
    for denom in [1u64, 4, 16, 64, 256] {
        let s = U280.fraction(denom);
        let f = U280.max_freq_mhz * 1e6;
        let r = roofline::lutmul_peak(&s, 4, f) / roofline::dsp_peak(&s, 4, f);
        println!("  1/{denom:<4} -> {r:.2}x");
    }
}
