//! Bench E6 — Table 2 regeneration: synthesize the full MobileNetV2
//! LUTMUL design (folding optimizer + resource/power/timing models) and
//! print the comparison rows, timing the whole harness.
//!
//! Run: `cargo bench --bench bench_table2`

use lutmul::util::bench::bench;

fn main() {
    println!("== E6: Table 2 regeneration ==\n");
    lutmul::reports::table2();
    println!();
    bench("table2: optimize_folding + synthesize (whole U280)", 10, || {
        lutmul::reports::our_design().fps()
    });
    bench("table2: paper-style design point (elem-serial input)", 10, || {
        lutmul::reports::paper_style_design().fps()
    });
    let arch = lutmul::graph::mobilenet_v2_full();
    bench("table2: baseline predictor (DSP packing, ZU9EG)", 100, || {
        lutmul::baselines::dsp_packing_accelerator(
            &arch,
            &lutmul::fabric::device::ZU9EG,
            8,
            333.0,
        )
        .fps
    });
}
