//! Bench E5 — Figure 6 regeneration + synthesis-analog performance:
//! per-layer LUT breakdowns and the folding optimizer across budgets.
//!
//! Run: `cargo bench --bench bench_synth`

use lutmul::fabric::device::U280;
use lutmul::graph::arch::{fig6_conv2, mobilenet_v2_full};
use lutmul::synth::breakdown::layer_breakdown;
use lutmul::synth::fold::{optimize_folding, Budget};
use lutmul::synth::synthesize;
use lutmul::util::bench::bench;

fn main() {
    println!("== E5: Figure 6 ==\n");
    lutmul::reports::fig6();
    println!();

    bench("fig6: single-layer breakdown", 10_000, || layer_breakdown(&fig6_conv2(), 1));

    let arch = mobilenet_v2_full();
    for denom in [1u64, 8, 64] {
        let budget =
            if denom == 1 { Budget::whole(&U280) } else { Budget::fraction(&U280, denom) };
        bench(&format!("fold optimizer: MobileNetV2, budget 1/{denom}"), 50, || {
            optimize_folding(&arch, &budget).1
        });
    }
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    bench("synthesize: MobileNetV2 full design", 200, || {
        synthesize(&arch, &U280, &folds).luts
    });

    // fold-sweep ablation for the Figure 6 layer
    println!("\nfig6 layer LUTs vs fold (ROM is storage, compute folds away):");
    println!("{:>6}{:>12}{:>12}{:>12}", "fold", "ROM", "adder+thr", "total");
    for fold in [1usize, 2, 4, 8, 16, 32] {
        let b = layer_breakdown(&fig6_conv2(), fold);
        println!(
            "{:>6}{:>12.0}{:>12.0}{:>12.0}",
            fold,
            b.impl_rom_luts,
            b.impl_adder_luts + b.threshold_luts,
            b.impl_total_luts
        );
    }
}
