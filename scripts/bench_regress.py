#!/usr/bin/env python3
"""Bench-trajectory regression gate (EXPERIMENTS.md E15).

Usage: bench_regress.py BASELINE.json NEW.json [--tolerance 0.20]

Compares the freshly measured ``images_per_s`` of every (backend,
datapath, sparsity) row in NEW.json against the committed baseline and
exits nonzero when any matching row dropped by more than the tolerance
(default 20%). Rows only present on one side are reported but never
fail the gate — backends come and go with features and runners, and a
run with ``--sparsity`` adds pruned rows (keyed by their sparsity, so
they can never collide with — or silently gate against — the dense
trajectory; dense rows omit the field and key as sparsity 0).

Skips (exit 0) when the baseline has no measured rows yet or is marked
as a placeholder, so the gate arms itself automatically on the first
commit of a measured BENCH_kernels.json.
"""

import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_key(doc):
    return {
        (r["backend"], r["datapath"], float(r.get("sparsity", 0.0))): r
        for r in doc.get("rows", [])
    }


def key_name(key):
    backend, datapath, sparsity = key
    suffix = f"@sparsity{sparsity:g}" if sparsity else ""
    return f"{backend}/{datapath}{suffix}"


def main(argv):
    if len(argv) < 3:
        print(__doc__.strip().splitlines()[2])
        return 2
    tolerance = 0.20
    if "--tolerance" in argv:
        tolerance = float(argv[argv.index("--tolerance") + 1])
    base = load(argv[1])
    new = load(argv[2])

    note = str(base.get("note", "")) + str(base.get("source", ""))
    if not base.get("rows"):
        print(f"bench-regress: baseline {argv[1]} has no measured rows yet — skipping")
        return 0
    if "placeholder" in note.lower():
        print(f"bench-regress: baseline {argv[1]} is marked placeholder — skipping")
        return 0

    base_rows = rows_by_key(base)
    new_rows = rows_by_key(new)
    failed = []
    for key, b in sorted(base_rows.items()):
        n = new_rows.get(key)
        name = key_name(key)
        if n is None:
            print(f"bench-regress: {name}: row gone from new run (not a failure)")
            continue
        if not n.get("bit_exact", False):
            failed.append(f"{name}: new run is not bit-exact")
            continue
        old_ips, new_ips = float(b["images_per_s"]), float(n["images_per_s"])
        ratio = new_ips / old_ips if old_ips > 0 else float("inf")
        verdict = "FAIL" if ratio < 1.0 - tolerance else "ok"
        print(
            f"bench-regress: {name}: {old_ips:.0f} -> {new_ips:.0f} img/s "
            f"({ratio:.2f}x, floor {1.0 - tolerance:.2f}x) {verdict}"
        )
        if verdict == "FAIL":
            failed.append(f"{name}: {old_ips:.0f} -> {new_ips:.0f} img/s ({ratio:.2f}x)")
    for key in sorted(set(new_rows) - set(base_rows)):
        print(f"bench-regress: {key_name(key)}: new row (no baseline, not gated)")

    if failed:
        print(f"bench-regress: {len(failed)} regression(s) beyond {tolerance:.0%}:")
        for f in failed:
            print(f"  {f}")
        return 1
    print("bench-regress: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
