#!/usr/bin/env python3
"""Bench-trajectory regression gate (EXPERIMENTS.md E15).

Usage: bench_regress.py BASELINE.json NEW.json [--tolerance 0.20]
       bench_regress.py --selftest

Compares the freshly measured ``images_per_s`` of every (backend,
datapath, sparsity, approx) row in NEW.json against the committed
baseline and exits nonzero when any matching row dropped by more than
the tolerance (default 20%). Rows only present on one side are reported
but never fail the gate — backends come and go with features and
runners, and a run with ``--sparsity`` adds pruned rows (keyed by their
sparsity, so they can never collide with — or silently gate against —
the dense trajectory; dense rows omit the field and key as sparsity 0).
Maddness-approximate rows (``lutmul eval --json``) carry ``"approx":
true`` and key separately the same way, so the approximate datapath's
throughput trajectory never gates against the exact one. Eval rows have
no ``bit_exact`` field (they chart accuracy instead); bit-exactness is
only enforced on rows that claim it.

Skips (exit 0) when the baseline has no measured rows yet or is marked
as a placeholder, so the gate arms itself automatically on the first
commit of a measured BENCH_kernels.json.

``--selftest`` runs the built-in unit checks (keying, gating, skip
logic) with no files needed — wired into `make eval-smoke` / CI so the
gate's own logic is tested on every run.
"""

import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def rows_by_key(doc):
    return {
        (
            r["backend"],
            r["datapath"],
            float(r.get("sparsity", 0.0)),
            bool(r.get("approx", False)),
        ): r
        for r in doc.get("rows", [])
    }


def key_name(key):
    backend, datapath, sparsity, approx = key
    suffix = f"@sparsity{sparsity:g}" if sparsity else ""
    if approx:
        suffix += "@approx"
    return f"{backend}/{datapath}{suffix}"


def gate(base, new, tolerance, out=print):
    """Core comparison: returns the list of failure strings."""
    note = str(base.get("note", "")) + str(base.get("source", ""))
    if not base.get("rows"):
        out("bench-regress: baseline has no measured rows yet — skipping")
        return []
    if "placeholder" in note.lower():
        out("bench-regress: baseline is marked placeholder — skipping")
        return []

    base_rows = rows_by_key(base)
    new_rows = rows_by_key(new)
    failed = []
    for key, b in sorted(base_rows.items()):
        n = new_rows.get(key)
        name = key_name(key)
        if n is None:
            out(f"bench-regress: {name}: row gone from new run (not a failure)")
            continue
        if "bit_exact" in n and not n["bit_exact"]:
            failed.append(f"{name}: new run is not bit-exact")
            continue
        old_ips, new_ips = float(b["images_per_s"]), float(n["images_per_s"])
        ratio = new_ips / old_ips if old_ips > 0 else float("inf")
        verdict = "FAIL" if ratio < 1.0 - tolerance else "ok"
        out(
            f"bench-regress: {name}: {old_ips:.0f} -> {new_ips:.0f} img/s "
            f"({ratio:.2f}x, floor {1.0 - tolerance:.2f}x) {verdict}"
        )
        if verdict == "FAIL":
            failed.append(f"{name}: {old_ips:.0f} -> {new_ips:.0f} img/s ({ratio:.2f}x)")
    for key in sorted(set(new_rows) - set(base_rows)):
        out(f"bench-regress: {key_name(key)}: new row (no baseline, not gated)")
    return failed


def selftest():
    """Unit checks for the keying and gating logic (no files needed)."""
    quiet = lambda *_: None  # noqa: E731

    def row(backend, ips, bit_exact=True, **extra):
        r = {
            "backend": backend,
            "datapath": "lut-fabric",
            "images_per_s": ips,
            "bit_exact": bit_exact,
        }
        r.update(extra)
        return r

    # sparsity and approx split the key space: four same-name rows key apart
    doc = {
        "rows": [
            row("executor", 100.0),
            row("executor", 90.0, sparsity=0.5),
            row("executor", 80.0, approx=True),
            row("executor", 70.0, sparsity=0.5, approx=True),
        ]
    }
    keys = rows_by_key(doc)
    assert len(keys) == 4, keys
    assert ("executor", "lut-fabric", 0.0, False) in keys
    assert ("executor", "lut-fabric", 0.5, True) in keys
    names = sorted(key_name(k) for k in keys)
    assert names[0] == "executor/lut-fabric", names
    assert "executor/lut-fabric@approx" in names
    assert "executor/lut-fabric@sparsity0.5" in names
    assert "executor/lut-fabric@sparsity0.5@approx" in names

    # a >tolerance drop on a matching key fails; unmatched rows never do
    base = {"rows": [row("executor", 100.0), row("gone", 50.0)]}
    new = {"rows": [row("executor", 70.0), row("fresh", 10.0)]}
    failed = gate(base, new, 0.20, out=quiet)
    assert len(failed) == 1 and "executor" in failed[0], failed

    # within tolerance passes
    assert gate(base, {"rows": [row("executor", 85.0)]}, 0.20, out=quiet) == []

    # an approx row never gates against the exact row of the same backend
    base = {"rows": [row("executor", 100.0)]}
    new = {"rows": [row("executor", 10.0, approx=True)]}
    assert gate(base, new, 0.20, out=quiet) == []

    # a bit-inexact row fails; an eval row without the field does not
    base = {"rows": [row("executor", 100.0)]}
    assert gate(base, {"rows": [row("executor", 100.0, bit_exact=False)]}, 0.2, out=quiet)
    eval_row = {
        "backend": "executor",
        "datapath": "lut-fabric",
        "images_per_s": 100.0,
        "top1": 0.9,
    }
    assert gate(base, {"rows": [eval_row]}, 0.2, out=quiet) == []

    # placeholder / empty baselines skip
    assert gate({"rows": [], "note": ""}, new, 0.2, out=quiet) == []
    assert gate({"rows": [row("x", 1.0)], "note": "PLACEHOLDER"}, new, 0.2, out=quiet) == []

    print("bench-regress --selftest: OK")
    return 0


def main(argv):
    if "--selftest" in argv:
        return selftest()
    if len(argv) < 3:
        print(__doc__.strip().splitlines()[2])
        return 2
    tolerance = 0.20
    if "--tolerance" in argv:
        tolerance = float(argv[argv.index("--tolerance") + 1])
    base = load(argv[1])
    new = load(argv[2])

    failed = gate(base, new, tolerance)
    if failed:
        print(f"bench-regress: {len(failed)} regression(s) beyond {tolerance:.0%}:")
        for f in failed:
            print(f"  {f}")
        return 1
    print("bench-regress: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
