# Build-time targets. `artifacts` runs the L1/L2 Python layer ONCE
# (train -> streamline -> AOT HLO + network.json, see DESIGN.md S15/S16);
# everything else in the repo is pure Rust and needs nothing from here.

PYTHON ?= python3

.PHONY: artifacts artifacts-fig2 test-python test-rust

artifacts:
	mkdir -p artifacts
	cd python && $(PYTHON) -m compile.aot --out ../artifacts/model.hlo.txt

# Figure 2 accuracy sweep on top of the regular artifacts (EXPERIMENTS.md E3)
artifacts-fig2:
	mkdir -p artifacts
	cd python && $(PYTHON) -m compile.aot --out ../artifacts/model.hlo.txt --fig2

test-python:
	cd python && $(PYTHON) -m pytest -q

test-rust:
	cd rust && cargo test -q
