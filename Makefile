# Build-time targets. `artifacts` runs the L1/L2 Python layer ONCE
# (train -> streamline -> AOT HLO + network.json, see DESIGN.md S15/S16);
# everything else in the repo is pure Rust and needs nothing from here.

PYTHON ?= python3

.PHONY: artifacts artifacts-fig2 test-python test-rust bench-smoke multi-smoke engine-smoke kernel-smoke prune-smoke serve-smoke fleet-smoke eval-smoke bench-json bench-regress doc lint

artifacts:
	mkdir -p artifacts
	cd python && $(PYTHON) -m compile.aot --out ../artifacts/model.hlo.txt

# Figure 2 accuracy sweep on top of the regular artifacts (EXPERIMENTS.md E3)
artifacts-fig2:
	mkdir -p artifacts
	cd python && $(PYTHON) -m compile.aot --out ../artifacts/model.hlo.txt --fig2

test-python:
	cd python && $(PYTHON) -m pytest -q

test-rust:
	cd rust && cargo test -q

# One-iteration batch/plan bench (EXPERIMENTS.md E9/E10): prints the
# acceptance lines (batch scaling >= 2x, plan compilation >= 3x on
# LutFabric) without the full sweep.
bench-smoke:
	cd rust && cargo bench --bench bench_batch -- --smoke

# Sharded-chain equivalence smoke (EXPERIMENTS.md E11): execute 2- and
# 3-way ShardChains on the small network (synthetic twin when the
# artifacts are absent), assert bit-exactness vs the single-device
# pipeline and measured-vs-analytic FPS within 15%. Exits nonzero on any
# divergence, so CI gates on it.
multi-smoke:
	cd rust && cargo run --release -- multi --devices 2 --run --n 8
	cd rust && cargo run --release -- multi --devices 3 --run --n 8

# Engine backend-comparison smoke (DESIGN.md S19, EXPERIMENTS.md E12):
# run every available InferenceBackend (executor, pipeline, 2-/3-way
# sharded chains, PJRT when loadable, LUT-fabric datapath) on the same
# inputs via `lutmul bench --backends all`. Prints a bit-exactness +
# throughput table and exits nonzero on any divergence, so CI gates on
# it. Synthetic fallback: runs on a fresh checkout without artifacts.
engine-smoke:
	cd rust && cargo run --release -- bench --backends all --n 6

# Kernel smoke (DESIGN.md S20/S22, EXPERIMENTS.md E13/E15): the
# LUT-GEMM table-layout gate (activation-major >= 1.2x MAC-major
# single-thread under --smoke's noise floor; the full
# `cargo bench --bench bench_kernels` gates >= 1.5x) PLUS the
# batch-major gate (batch-major sweep >= 1.5x the image-major act-major
# driver at batch 8 single-thread, same bar in both modes — warmup +
# median-of-k timing keeps the ratio stable), bit-exactness across
# every table layout and batch driver, the counting-allocator
# zero-allocation test (batch-major and image-major steady state), the
# arena + batch-major property suites, and the cross-backend
# bit-identity table. Exits nonzero on any regression or divergence, so
# CI gates on it.
kernel-smoke:
	cd rust && cargo bench --bench bench_kernels -- --smoke
	cd rust && cargo test -q --test zero_alloc --test kernels_arena --test kernels_batch
	cd rust && cargo run --release -- bench --backends all --n 6

# Structured-pruning smoke (DESIGN.md S23, EXPERIMENTS.md E16): the
# bench harness's prune gate (compacted 50%-channel-sparsity plan
# bit-exact vs the dense compile of the masked network AND >= 1.3x its
# single-thread batch-major throughput), the prune conformance property
# suite (all four datapaths x batch 1..=17 x both drivers vs masked
# dense, fold-rescaled pipeline logits + analytic-vs-simulated FPS), and
# the sparse rows of the engine comparison. Exits nonzero on any
# divergence or a missed speedup, so CI gates on it.
prune-smoke:
	cd rust && cargo bench --bench bench_kernels -- --smoke
	cd rust && cargo test -q --test prune
	cd rust && cargo run --release -- bench --backends all --n 6 --sparsity 0.5
	cd rust && cargo run --release -- report prune --sparsity 0.5 --n 6

# Approximate-datapath accuracy smoke (DESIGN.md S24, EXPERIMENTS.md
# E17): the eval conformance suite (labeled-synthetic determinism,
# exact datapaths at 100%, saturated approx bit-exact, learned approx
# above the seeded agreement floor, stable Pareto JSON schema,
# executor-vs-pipeline approx bit-identity), then `lutmul eval` twice —
# the saturated configuration gated at top-1 == 1.0 (bit-exact by
# construction) and the learned default gated at the conservative 0.05
# agreement floor — plus the area/cycle report's saturated witness and
# the regression script's own selftest. Exits nonzero on any violation,
# so CI gates on it.
eval-smoke:
	cd rust && cargo test -q --test eval
	cd rust && cargo run --release -- eval --n 32 --saturated --floor 1.0
	cd rust && cargo run --release -- eval --n 32 --pareto --sparsity 0.5 --floor 0.05
	cd rust && cargo run --release -- report approx --n 4
	$(PYTHON) scripts/bench_regress.py --selftest

# Bench-trajectory regression gate (EXPERIMENTS.md E15): regenerate the
# machine-readable rows into a scratch file and diff images_per_s
# against the committed BENCH_kernels.json — fails on a >20% drop for
# any matching (backend, datapath) row; skips gracefully while the
# committed baseline has no measured rows.
bench-regress:
	cd rust && cargo run --release -- bench --backends all --n 8 --json > ../BENCH_new.json
	$(PYTHON) scripts/bench_regress.py BENCH_kernels.json BENCH_new.json
	rm -f BENCH_new.json

# Serving-tier smoke (DESIGN.md S21, EXPERIMENTS.md E14): the serve/chaos
# integration suites (ordering, bit-exactness across the wire, worker
# failure/rebuild, socket-driven backpressure, deadline sheds), then
# `lutmul loadgen --smoke` — a self-hosted TCP server under calibrated
# open-loop steady/burst/shed phases, gated on zero lost requests, zero
# reordering, sustained goodput, a bounded p99 and a live shed path.
# Exits nonzero on any violation, so CI gates on it.
serve-smoke:
	cd rust && cargo test -q --test serve --test chaos
	cd rust && cargo run --release -- loadgen --smoke --duration-ms 600

# Heterogeneous-fleet smoke (DESIGN.md S25, EXPERIMENTS.md E18): the
# fleet chaos/elasticity suite (mid-batch ShardChain kill with zero
# lost/reordered requests and monotonic occupancy, retry-budget
# exhaustion to the typed shed, autoscale up under a burst and
# drain-then-retire back to the floor, class routing, total-loss
# shutdown resolution), then `lutmul loadgen --fleet-smoke` — a
# self-hosted fleet server under mixed-class open-loop load with a
# chaos kill mid-phase — and `lutmul report fleet`, which walks the
# whole elastic envelope in-process and gates every invariant. Exits
# nonzero on any violation, so CI gates on it.
fleet-smoke:
	cd rust && cargo test -q --test fleet
	cd rust && cargo run --release -- loadgen --fleet-smoke --duration-ms 600
	cd rust && cargo run --release -- report fleet --requests 64

# Machine-readable perf trajectory (EXPERIMENTS.md E13): one
# {backend, datapath, images_per_s, ns_per_image, bit_exact} row per
# backend, written to BENCH_kernels.json at the repo root. Regenerate
# after any kernel/backend perf change and commit the file so the
# trajectory is tracked in-tree.
bench-json:
	cd rust && cargo run --release -- bench --backends all --n 8 --json > ../BENCH_kernels.json
	@echo "wrote BENCH_kernels.json"

# API docs with rustdoc warnings (dangling doc links) denied.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

lint:
	cd rust && cargo fmt --check && cargo clippy -- -D warnings
